//! KV-cache residency model.
//!
//! Each stack owns a bounded cache budget for the K/V tensors of
//! in-flight generations. The budget models the two places cached
//! activations can physically live in the HeTraX stack: the SM-MC
//! tiers' DRAM-side staging (behind the MCs) and the spare SRAM/buffer
//! capacity of the ReRAM tier — split by `sm_frac`, filled SM-side
//! first. Admission charges a request's *peak* footprint (its cache at
//! EOS, [`crate::model::DecodeWorkload::peak_kv_bytes`]) up front, so
//! an admitted generation can never be evicted mid-flight — refusal
//! happens at the door, not after tokens have streamed. Actual
//! occupancy (what telemetry reports) grows token by token and is
//! released at retirement. Chunked prefill charges the same peak
//! reservation at its *first admitted chunk* and grows occupancy chunk
//! by chunk, so a mid-chunking prompt is as safe from eviction as a
//! running generation.
//!
//! Two consumers share this model: the decode scheduler (the
//! authoritative accountant, whose *actual* reservations feed the live
//! `kv_committed_bytes` routing signal in
//! [`crate::cluster::StackSnapshot`]) and the retired pre-pass
//! residency model in [`crate::cluster::prepass`], kept only as the
//! `cluster_routing` bench baseline. Accounting rules: DESIGN.md
//! §Decode.

/// Per-stack cache budget.
#[derive(Debug, Clone, Copy)]
pub struct KvCacheConfig {
    /// Total cache bytes per stack.
    pub capacity_bytes: f64,
    /// Share of the budget on the SM-MC tiers; the rest sits in the
    /// ReRAM tier's buffers. Placement is fill-SM-first.
    pub sm_frac: f64,
}

impl Default for KvCacheConfig {
    fn default() -> Self {
        // 128 MiB split evenly: enough for ~10 concurrent bert-base
        // generations at mixed prompt lengths — small enough that
        // sustained load exercises the admission path.
        KvCacheConfig { capacity_bytes: 128.0 * 1024.0 * 1024.0, sm_frac: 0.5 }
    }
}

impl KvCacheConfig {
    /// Split `bytes` of resident cache across the tiers (fill-SM-first).
    pub fn split(&self, bytes: f64) -> (f64, f64) {
        let sm_cap = self.capacity_bytes * self.sm_frac.clamp(0.0, 1.0);
        let sm = bytes.min(sm_cap);
        (sm, bytes - sm)
    }
}

/// One stack's residency accountant: peak-byte reservations plus actual
/// occupancy. Pure arithmetic on simulated quantities — deterministic,
/// which is what keeps both its consumers (the scheduler's live
/// accounting and the pre-pass bench baseline) inside the
/// byte-identical contract.
#[derive(Debug, Clone)]
pub struct KvPool {
    pub cfg: KvCacheConfig,
    reserved: f64,
    used: f64,
    /// High-water mark of actual occupancy.
    pub peak_used: f64,
}

impl KvPool {
    pub fn new(cfg: KvCacheConfig) -> KvPool {
        KvPool { cfg, reserved: 0.0, used: 0.0, peak_used: 0.0 }
    }

    pub fn capacity_bytes(&self) -> f64 {
        self.cfg.capacity_bytes
    }

    /// Would an additional `need` bytes of reservation fit right now?
    pub fn would_fit(&self, need: f64) -> bool {
        self.reserved + need <= self.cfg.capacity_bytes + 1e-6
    }

    /// Reserve a request's peak footprint; false when it does not fit.
    pub fn try_reserve(&mut self, peak: f64) -> bool {
        if !self.would_fit(peak) {
            return false;
        }
        self.reserved += peak;
        true
    }

    /// Charge a reservation even past the budget. The scheduler never
    /// does this; it exists for the retired pre-pass residency model
    /// ([`crate::cluster::prepass`], the bench baseline), which commits
    /// queued work to a stack before the stack has the headroom to
    /// start it — the pool then runs overcommitted until the releases
    /// it is waiting on happen, and `would_fit` correctly reports the
    /// stack as saturated in the meantime.
    pub fn reserve_queued(&mut self, bytes: f64) {
        self.reserved += bytes;
    }

    /// Account bytes actually written (prefill KV, then one append per
    /// generated token).
    pub fn grow(&mut self, bytes: f64) {
        self.used += bytes;
        self.peak_used = self.peak_used.max(self.used);
    }

    /// Release a retired request's reservation and occupancy.
    pub fn release(&mut self, peak: f64, used: f64) {
        self.reserved = (self.reserved - peak).max(0.0);
        self.used = (self.used - used).max(0.0);
    }

    pub fn reserved_bytes(&self) -> f64 {
        self.reserved
    }

    pub fn used_bytes(&self) -> f64 {
        self.used
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(cap: f64) -> KvPool {
        KvPool::new(KvCacheConfig { capacity_bytes: cap, sm_frac: 0.5 })
    }

    #[test]
    fn reserve_refuse_release_cycle() {
        let mut p = pool(100.0);
        assert!(p.try_reserve(60.0));
        assert!(!p.try_reserve(60.0), "second reservation exceeds capacity");
        assert!(p.try_reserve(40.0));
        assert_eq!(p.reserved_bytes(), 100.0);
        p.grow(30.0);
        p.grow(20.0);
        assert_eq!(p.used_bytes(), 50.0);
        assert_eq!(p.peak_used, 50.0);
        p.release(60.0, 30.0);
        assert_eq!(p.reserved_bytes(), 40.0);
        assert_eq!(p.used_bytes(), 20.0);
        assert!(p.try_reserve(60.0), "freed reservation is reusable");
        // Peak is a high-water mark, not current occupancy.
        assert_eq!(p.peak_used, 50.0);
    }

    #[test]
    fn queued_reservation_overcommits_until_release() {
        // The router-model path: committing queued work past the budget
        // must mark the pool saturated until enough releases land.
        let mut p = pool(100.0);
        assert!(p.try_reserve(80.0));
        p.reserve_queued(50.0);
        assert_eq!(p.reserved_bytes(), 130.0);
        assert!(!p.would_fit(10.0), "overcommitted pool is saturated");
        p.release(80.0, 0.0);
        assert_eq!(p.reserved_bytes(), 50.0);
        assert!(p.try_reserve(50.0), "headroom returns once releases land");
    }

    #[test]
    fn tier_split_fills_sm_first() {
        let cfg = KvCacheConfig { capacity_bytes: 100.0, sm_frac: 0.25 };
        assert_eq!(cfg.split(10.0), (10.0, 0.0));
        assert_eq!(cfg.split(25.0), (25.0, 0.0));
        assert_eq!(cfg.split(60.0), (25.0, 35.0));
        let (sm, reram) = cfg.split(100.0);
        assert_eq!(sm + reram, 100.0);
    }

    #[test]
    fn default_budget_admits_several_bert_base_generations() {
        use crate::model::{ArchVariant, DecodeWorkload, ModelId};
        let dw = DecodeWorkload::build(ModelId::BertBase, ArchVariant::EncoderOnly);
        let peak = dw.peak_kv_bytes(256, 64);
        let mut p = KvPool::new(KvCacheConfig::default());
        let mut admitted = 0;
        while p.try_reserve(peak) {
            admitted += 1;
        }
        assert!(admitted >= 4, "default budget too small: {admitted}");
        assert!(admitted < 64, "default budget should bound concurrency: {admitted}");
    }
}
