//! S12 — Autoregressive decode subsystem: request lifecycles, KV-cache
//! residency, and continuous batching on top of the serving engine and
//! traffic stack.
//!
//! The serve/loadtest path models a request as one one-shot prefill
//! batch; real generative traffic is prefill + N decode steps with
//! per-token occupancy. The decode phase lives in a different regime —
//! GEMV-shaped projections bound by weight streaming, attention bound
//! by KV-cache reads that grow with context — which is exactly the
//! prefill/decode split the heterogeneous-serving literature builds on
//! (Sharma et al., arXiv:2312.11750; Kim et al., arXiv:2302.14017).
//!
//! * [`crate::model::decode`] — per-step cost constants derived from
//!   the `Workload::build` closed forms at one query position, plus
//!   KV-footprint accounting.
//! * [`engine`] — costs → seconds on the two tier resources, with the
//!   batch-shared weight stream that makes continuous batching pay.
//! * [`kv`] — per-stack KV-cache residency: peak-footprint reservation
//!   at admission (refusal at the door, never mid-flight eviction),
//!   budget split across the SM-MC and ReRAM tiers.
//! * [`scheduler`] — the continuous-batching loop: prefill-prioritized
//!   joins, step-level clock, EOS retirement from the generator's
//!   seeded output lengths, thermal admission via the existing
//!   [`crate::traffic::AdmissionController`] with the running batch
//!   priced as un-throttleable background, and chunked prefill
//!   (`chunk_tokens`) bounding every prefill action so long prompts
//!   interleave with decode steps instead of stalling them. The loop
//!   is packaged as the resumable [`scheduler::DecodeStack`] —
//!   `step_until(t)` advances a stack to an arrival instant without
//!   finishing its run — so the cluster co-simulation core
//!   (`crate::cluster`) can interleave all stacks in lockstep virtual
//!   time and route every arrival against live state.
//! * [`telemetry`] — TTFT / TPOT / ITL / e2e histograms, KV occupancy,
//!   lifecycle counters.
//! * [`decodetest`] — orchestration (generate → cluster-driven lockstep
//!   serve → aggregate) emitting the deterministic `BENCH_decode.json`
//!   (schema: DESIGN.md §Decode); exposed as `hetrax decodetest`.
//!
//! Determinism: same contract as the traffic subsystem — seeded draws
//! happen before serving, the cluster event loop is ordered by
//! `(virtual_time, stack_idx, seq_no)`, stacks are pure functions of
//! their push/step sequences, folds are in stack order; byte-identical
//! across runs and `HETRAX_THREADS` values.

pub mod decodetest;
pub mod engine;
pub mod kv;
pub mod scheduler;
pub mod telemetry;

pub use decodetest::{run, run_with_faults, DecodeReport};
pub use engine::{DecodeEngine, StepCost, StepGroup};
pub use kv::{KvCacheConfig, KvPool};
pub use scheduler::{
    Completion, DecodeConfig, DecodeStack, DecodeStackOutcome, KvHandoff,
};
pub use telemetry::DecodeTelemetry;
