//! Decode-step timing: [`crate::model::DecodeWorkload`] costs → seconds
//! on the two tier resources, with the batch-amortization structure that
//! makes continuous batching pay.
//!
//! Roofline per block, mirroring `perf::timing`'s rates:
//!
//! * **Projections** (QKV/output, GEMV): the weight panels stream from
//!   MC L2 *once per step* regardless of batch size — only activations
//!   scale with B. This shared-weight term is the entire economic case
//!   for batching decode steps.
//! * **Attention**: per cached context entry — K/V rows stream per
//!   request, so the term scales with Σ context over the batch, not B.
//! * **FF** (ReRAM tier): weights resident in the crossbars, so the
//!   GEMV is pure crossbar throughput + TSV activation traffic.
//!
//! Everything is a pure function of config + batch composition: no
//! clocks, no randomness — the decode bench's byte-identical contract
//! rests on this module.

use std::collections::HashMap;

use crate::config::Config;
use crate::model::{ArchVariant, DecodeWorkload, ModelId};
use crate::perf::timing;
use crate::reram::FfMapping;

/// One (model, variant) slice of a decode step: `b` requests whose
/// self-/cross-attention context lengths sum to the given totals.
#[derive(Debug, Clone, Copy)]
pub struct StepGroup {
    pub model: ModelId,
    pub variant: ArchVariant,
    pub b: usize,
    pub sum_self_ctx: usize,
    pub sum_cross_ctx: usize,
}

/// What one decode step costs across every group.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepCost {
    /// SM-tier busy seconds.
    pub mha_s: f64,
    /// ReRAM-tier busy seconds.
    pub ff_s: f64,
    /// Wall-clock seconds the step occupies (MHA ∥ FF for
    /// parallel-attention variants, serial otherwise).
    pub wall_s: f64,
    /// SM-side FLOPs (projections + attention + element-wise).
    pub sm_flops: f64,
    /// ReRAM crossbar ops.
    pub ff_ops: f64,
    /// Bytes streamed through MC L2 (weights + activations).
    pub l2_bytes: f64,
    /// KV-cache bytes read (the DRAM-side residency traffic).
    pub kv_read_bytes: f64,
}

#[derive(Debug, Clone)]
struct DecodeEntry {
    dw: DecodeWorkload,
    ff_throughput_ops: f64,
    active_frac: f64,
}

/// Per-(model, variant) decode tables + the step-time evaluator.
#[derive(Debug, Clone)]
pub struct DecodeEngine<'a> {
    pub cfg: &'a Config,
    entries: HashMap<(ModelId, ArchVariant), DecodeEntry>,
}

impl<'a> DecodeEngine<'a> {
    /// Build tables for every key the request stream will touch.
    pub fn build(cfg: &'a Config, keys: &[(ModelId, ArchVariant)]) -> DecodeEngine<'a> {
        let mut entries = HashMap::new();
        for &(model, variant) in keys {
            entries.entry((model, variant)).or_insert_with(|| {
                let dw = DecodeWorkload::build(model, variant);
                let ff_map = FfMapping::map(cfg, dw.dims.d_model, dw.dims.d_ff);
                DecodeEntry {
                    dw,
                    ff_throughput_ops: ff_map.throughput_ops(cfg),
                    active_frac: ff_map.active_frac,
                }
            });
        }
        DecodeEngine { cfg, entries }
    }

    fn entry(&self, model: ModelId, variant: ArchVariant) -> &DecodeEntry {
        self.entries
            .get(&(model, variant))
            .unwrap_or_else(|| panic!("decode table missing for {model} {variant}"))
    }

    pub fn workload(&self, model: ModelId, variant: ArchVariant) -> &DecodeWorkload {
        &self.entry(model, variant).dw
    }

    /// Fraction of ReRAM tiles the model's FF mapping keeps active (the
    /// thermal model's `reram_active_frac` input).
    pub fn active_frac(&self, model: ModelId, variant: ArchVariant) -> f64 {
        self.entry(model, variant).active_frac
    }

    /// Attention surcharge for one prefill chunk: `new_tokens` fresh
    /// query positions attending over `ctx` previously cached prompt
    /// positions — the cross-chunk term the chunk-sized [`Workload`]
    /// priced through `Engine::serve_batch` cannot see (its attention
    /// covers only the chunk itself). GEMM regime: the cached K/V
    /// panels stream from the residency tier once per chunk and are
    /// shared by every query in it, so the byte term scales with `ctx`
    /// alone while the FLOP term scales with `new_tokens × ctx`. Runs
    /// on the SM tiers (`mha_s`); the ReRAM tier is untouched.
    ///
    /// [`Workload`]: crate::model::Workload
    pub fn chunk_attn_cost(
        &self,
        model: ModelId,
        variant: ArchVariant,
        new_tokens: usize,
        ctx: usize,
    ) -> StepCost {
        let mut total = StepCost::default();
        if new_tokens == 0 || ctx == 0 {
            return total;
        }
        let e = self.entry(model, variant);
        let dw = &e.dw;
        // The prompt flows through the encoder stack for cross-attention
        // variants, through every block otherwise.
        let blocks = if dw.cross {
            (dw.dims.layers - dw.step_blocks) as f64
        } else {
            dw.step_blocks as f64
        };
        let flops = blocks * new_tokens as f64 * ctx as f64 * dw.attn_flops_per_ctx;
        let bytes = blocks * ctx as f64 * dw.attn_bytes_per_ctx;
        let t = (flops / timing::sm_tier_gemm_flops(self.cfg))
            .max(bytes / timing::l2_stream_bw(self.cfg));
        total.mha_s = t;
        total.wall_s = t;
        total.sm_flops = flops;
        // Cached-context reads are DRAM-side KV traffic (step_cost's
        // convention: `l2_bytes` carries only weight/activation streams).
        total.kv_read_bytes = bytes;
        total
    }

    /// Cost of one decode step over the given groups. Groups are
    /// processed serially through the tiers; within a group the batch
    /// shares one weight stream.
    pub fn step_cost(&self, groups: &[StepGroup]) -> StepCost {
        let cfg = self.cfg;
        let gemm = timing::sm_tier_gemm_flops(cfg);
        let vecf = timing::sm_tier_vector_flops(cfg);
        let l2 = timing::l2_stream_bw(cfg);
        let tsv_bw = timing::tsv_stream_bw(cfg);

        let mut total = StepCost::default();
        for g in groups {
            let e = self.entry(g.model, g.variant);
            let dw = &e.dw;
            let b = g.b as f64;
            let blocks = dw.step_blocks as f64;
            let ctx = (g.sum_self_ctx + g.sum_cross_ctx) as f64;

            // Projections: weights once, activations per token.
            let t_gemv = (b * dw.gemv_flops_tok / gemm)
                .max((dw.gemv_weight_bytes + b * dw.gemv_act_bytes_tok) / l2);
            // Attention: scales with total cached context, not batch.
            let t_attn = (ctx * dw.attn_flops_per_ctx / gemm)
                .max(ctx * dw.attn_bytes_per_ctx / l2);
            let t_vec = b * dw.vec_flops_tok / vecf;
            let mha = blocks * (t_gemv + t_attn + t_vec);

            // FF GEMV: resident crossbars + TSV activation stream.
            let t_ff = (b * dw.ff_flops_tok / e.ff_throughput_ops)
                .max(b * dw.ff_act_bytes_tok / tsv_bw);
            let ff = blocks * t_ff;

            total.mha_s += mha;
            total.ff_s += ff;
            total.wall_s += if dw.variant.mha_ff_parallel() { mha.max(ff) } else { mha + ff };
            total.sm_flops +=
                blocks * (b * (dw.gemv_flops_tok + dw.vec_flops_tok) + ctx * dw.attn_flops_per_ctx);
            total.ff_ops += blocks * b * dw.ff_flops_tok;
            total.l2_bytes +=
                blocks * (dw.gemv_weight_bytes + b * dw.gemv_act_bytes_tok);
            total.kv_read_bytes += blocks * ctx * dw.attn_bytes_per_ctx;
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn group(b: usize, ctx_each: usize) -> StepGroup {
        StepGroup {
            model: ModelId::BertBase,
            variant: ArchVariant::EncoderOnly,
            b,
            sum_self_ctx: b * ctx_each,
            sum_cross_ctx: 0,
        }
    }

    fn engine(cfg: &Config) -> DecodeEngine<'_> {
        DecodeEngine::build(cfg, &[(ModelId::BertBase, ArchVariant::EncoderOnly)])
    }

    #[test]
    fn batching_amortizes_weight_streams() {
        // The decode economics: per-token step time must drop sharply
        // with batch size because the GEMV weight panels are shared.
        let cfg = Config::default();
        let e = engine(&cfg);
        let one = e.step_cost(&[group(1, 192)]);
        let eight = e.step_cost(&[group(8, 192)]);
        assert!(one.wall_s > 0.0 && eight.wall_s > one.wall_s);
        let per_tok_1 = one.wall_s;
        let per_tok_8 = eight.wall_s / 8.0;
        assert!(
            per_tok_8 < per_tok_1 * 0.5,
            "per-token {per_tok_8} vs serial {per_tok_1}"
        );
    }

    #[test]
    fn step_time_grows_with_context() {
        let cfg = Config::default();
        let e = engine(&cfg);
        let short = e.step_cost(&[group(4, 64)]);
        let long = e.step_cost(&[group(4, 2048)]);
        assert!(long.wall_s > short.wall_s, "KV reads must cost");
        assert!(long.kv_read_bytes > short.kv_read_bytes);
        // Busy split covers the wall clock for serial variants.
        assert!((short.mha_s + short.ff_s - short.wall_s).abs() < 1e-15);
    }

    #[test]
    fn decode_step_is_memory_bound_not_compute_bound() {
        // GEMV regime: at B=1 the projection term must sit on the L2
        // weight-stream roofline, far off the tensor-core peak.
        let cfg = Config::default();
        let e = engine(&cfg);
        let dw = *e.workload(ModelId::BertBase, ArchVariant::EncoderOnly);
        let sc = e.step_cost(&[group(1, 128)]);
        let compute_only =
            dw.step_blocks as f64 * dw.gemv_flops_tok / timing::sm_tier_gemm_flops(&cfg);
        assert!(
            sc.mha_s > 5.0 * compute_only,
            "decode should be weight-stream-bound: {} vs compute {}",
            sc.mha_s,
            compute_only
        );
    }

    #[test]
    fn chunk_attn_surcharge_scales_with_context_and_zeroes_out() {
        let cfg = Config::default();
        let e = engine(&cfg);
        let (m, v) = (ModelId::BertBase, ArchVariant::EncoderOnly);
        // No prior context (the first chunk) and no new tokens are free.
        assert_eq!(e.chunk_attn_cost(m, v, 64, 0).wall_s, 0.0);
        assert_eq!(e.chunk_attn_cost(m, v, 0, 64).wall_s, 0.0);
        // Grows with cached context and with chunk size; touches only
        // the SM tier and the KV read stream.
        let a = e.chunk_attn_cost(m, v, 64, 64);
        let b = e.chunk_attn_cost(m, v, 64, 448);
        assert!(a.wall_s > 0.0 && b.wall_s > a.wall_s);
        assert!(b.kv_read_bytes > a.kv_read_bytes);
        assert!(e.chunk_attn_cost(m, v, 128, 64).sm_flops > a.sm_flops);
        assert_eq!(a.ff_s, 0.0);
        assert_eq!(a.ff_ops, 0.0);
        assert_eq!(a.mha_s, a.wall_s);
    }

    #[test]
    fn mixed_groups_sum_and_tables_cover_keys() {
        let cfg = Config::default();
        let keys = [
            (ModelId::BertBase, ArchVariant::EncoderOnly),
            (ModelId::BartBase, ArchVariant::EncoderDecoder),
        ];
        let e = DecodeEngine::build(&cfg, &keys);
        let g1 = group(2, 128);
        let g2 = StepGroup {
            model: ModelId::BartBase,
            variant: ArchVariant::EncoderDecoder,
            b: 2,
            sum_self_ctx: 8,
            sum_cross_ctx: 256,
        };
        let both = e.step_cost(&[g1, g2]);
        let a = e.step_cost(&[g1]);
        let b = e.step_cost(&[g2]);
        assert!((both.wall_s - a.wall_s - b.wall_s).abs() < 1e-15);
        assert!((both.sm_flops - a.sm_flops - b.sm_flops).abs() < 1.0);
        assert!(e.active_frac(ModelId::BartBase, ArchVariant::EncoderDecoder) > 0.0);
    }
}
