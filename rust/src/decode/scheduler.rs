//! Continuous-batching decode scheduler: one stack's request-lifecycle
//! loop on a step-level simulated clock, exposed as a *resumable*
//! engine ([`DecodeStack`]) the cluster co-simulation core drives.
//!
//! Lifecycle (DESIGN.md §Decode): `Waiting → Prefilling → Decoding →
//! Retired`, with two refusal edges — `refused_kv` at ingest (the peak
//! cache footprint can never fit the stack budget) and `shed` when a
//! waiting request ages past the queue-wait bound (including thermal
//! deferrals that never clear).
//!
//! Scheduling policy: prefill-prioritized continuous batching. Whenever
//! the running batch has room and the thermal controller admits, the
//! head-of-queue run of compatible requests is prefilled as one batch
//! through [`Engine::serve_batch`] (the §4.2 two-tier pipeline, emitting
//! each request's first token); otherwise the whole running set advances
//! one decode step, every request appends one token to its KV cache, and
//! EOS retirements release their reservations. Tier busy time is
//! accounted through the same [`ServeState`]/[`Engine::serve_batch`]
//! horizons the serve path uses; operations issue in decision order
//! (decode's token-to-token dependency serializes them), while the B
//! requests of a prefill batch still pipeline across the two tiers
//! inside `serve_batch`.
//!
//! Chunked prefill (DESIGN.md §Decode): with `chunk_tokens > 0` every
//! prefill action is bounded by the token budget — whole-prompt batches
//! stop accepting members once their summed prompt tokens reach it, and
//! a single prompt longer than the budget prefills alone, chunk by
//! chunk. While generations are running, *every* prefill action (chunk
//! or whole batch) strictly alternates with decode steps, so neither a
//! long prompt nor a queue of short ones can stack stalls. Each chunk
//! is priced through the same [`Engine::serve_batch`] path (at the
//! chunk's length) plus the [`DecodeEngine::chunk_attn_cost`] surcharge
//! for attending over the already-cached prompt prefix, and is gated
//! per-chunk through
//! [`AdmissionController::admit_with_background`]. The worst-case gap
//! between the running set's tokens — the ITL spike the serving
//! literature attributes to head-of-line prefills — is therefore
//! bounded by one budget-sized prefill action plus one decode step.
//! `chunk_tokens = 0` disables the lane and keeps the original
//! whole-prompt path bit for bit (every chunking branch sits behind
//! that gate).
//!
//! **Resumable stepping** (DESIGN.md §Cluster): the loop's whole state
//! lives in [`DecodeStack`]. [`ClusterStack::step_until`] executes
//! every decision whose instant falls strictly before a deadline
//! (actions are atomic — one started before the deadline may finish
//! past it, exactly as the pre-cluster serial loop behaved);
//! [`ClusterStack::push`] appends a routed arrival;
//! [`DecodeStack::finish`] runs to completion and extracts the outcome.
//! Because per-stack decisions only ever read arrivals at or before the
//! stack's clock, pushing the whole stream up front (`serve_stack`) and
//! interleaving pushes with deadline stepping (the cluster) produce
//! byte-identical outcomes — the
//! refactor's equivalence pin. The stack also maintains the live
//! telemetry routing consumes ([`StackSnapshot`]): the horizon ledger
//! (`max(horizon, arrival) + est_service` per accepted request — the
//! retired pre-pass JSQ arithmetic, which is why live JSQ reproduces
//! it), committed KV bytes (actual pool reservations plus queued
//! peaks), and rolling TTFT/ITL EWMAs.
//!
//! Determinism: the loop reads only simulated quantities — arrivals and
//! sampled output lengths come pre-drawn from the seeded generator, the
//! thermal controller is deterministic, and every fold is in a fixed
//! order. A stack's outcome is a pure function of its push/step
//! sequence.

use std::collections::HashMap;
use std::collections::VecDeque;

use crate::cluster::{self, ClusterStack, HealthState, StackSnapshot};
use crate::config::Config;
use crate::coordinator::{Batch, Engine, Request, ServeState};
use crate::decode::engine::{DecodeEngine, StepGroup};
use crate::decode::kv::{KvCacheConfig, KvPool};
use crate::decode::telemetry::DecodeTelemetry;
use crate::fleet::{self, StackArch, StackArchId};
use crate::model::{ArchVariant, ModelId};
use crate::obs::{Outcome, Recorder, WindowSample, DECODE_STEP_SAMPLE};
use crate::power;
use crate::traffic::admission::{AdmissionController, BatchCost, ThrottleConfig};
use crate::traffic::generator::{ArrivalPattern, RequestMix};
use crate::traffic::phases::{PhaseInfo, PhaseKey};
use crate::traffic::router::RoutePolicy;

/// Full parameterization of one decode run (`hetrax decodetest`).
#[derive(Debug, Clone)]
pub struct DecodeConfig {
    pub pattern: ArrivalPattern,
    /// Must carry an output-length distribution for generation traffic;
    /// requests with `out_tokens == 0` are clamped to one token.
    pub mix: RequestMix,
    pub duration_s: f64,
    pub stacks: usize,
    pub policy: RoutePolicy,
    pub seed: u64,
    pub kv: KvCacheConfig,
    /// Continuous-batch capacity: how many generations decode together.
    /// 1 = one-request-at-a-time serving (the regression baseline).
    pub max_running: usize,
    /// Cap on requests prefilled together in one batch.
    pub max_prefill_batch: usize,
    /// Chunked-prefill token budget: the most prompt tokens one prefill
    /// action may process. 0 disables chunking (whole prompts prefill
    /// in one batch — the pre-chunking behaviour, bit for bit). Prompts
    /// longer than the budget prefill chunk by chunk, interleaved with
    /// decode steps, bounding the worst-case inter-token stall of the
    /// running generations.
    pub chunk_tokens: usize,
    /// Thermal admission knobs (ceiling, control window, queue-wait
    /// bound) — shared with the loadtest controller.
    pub throttle: ThrottleConfig,
    /// Worker threads for the phase-table fan-out (0 = auto, 1 =
    /// serial); results are identical at any value. Stack stepping is
    /// serial — the cluster event loop's determinism is structural.
    pub threads: usize,
    /// Per-stack architecture presets ([`StackArchId`]): empty means
    /// every stack is `hetrax3d` (the exact default silicon); a single
    /// entry broadcasts to all stacks; otherwise the length must equal
    /// `stacks` (the CLI validates).
    pub archs: Vec<StackArchId>,
    /// Cluster stepping strategy ([`cluster::Stepper`], default
    /// indexed). The `cluster::testkit` grid pins the two bit-identical;
    /// the linear oracle stays selectable for the equivalence harness
    /// and for bisection when a new stack type lands.
    pub stepper: cluster::Stepper,
    /// JSQ(d) snapshot sampling: per arrival the router snapshots only
    /// `sample_d` seeded-random candidate stacks instead of all of them.
    /// 0 (default) and any `d >= stacks` mean full snapshots —
    /// bit-identical to the pre-sampling router.
    pub sample_d: usize,
    /// Arrival-stream look-ahead (requests buffered at a time) for the
    /// live run path: the generator is consumed as a bounded iterator
    /// and arrivals are dropped once routed, so memory is O(stacks +
    /// in-flight) regardless of `duration_s`. 0 materializes the whole
    /// stream up front (the legacy memory profile). Results are
    /// byte-identical at every value — the `cluster::testkit` grid pins
    /// {1, 64, 0}. Pre-pass routing replays a whole-stream assignment
    /// and always materializes.
    pub stream_chunk: usize,
}

impl DecodeConfig {
    pub fn new(pattern: ArrivalPattern, mix: RequestMix) -> DecodeConfig {
        DecodeConfig {
            pattern,
            mix,
            duration_s: 1.0,
            stacks: 1,
            policy: RoutePolicy::JoinShortestQueue,
            seed: 0xC0DE,
            kv: KvCacheConfig::default(),
            max_running: 8,
            max_prefill_batch: 4,
            chunk_tokens: 0,
            throttle: ThrottleConfig::default(),
            threads: 0,
            archs: Vec::new(),
            stepper: cluster::Stepper::default(),
            sample_d: 0,
            stream_chunk: 1024,
        }
    }
}

/// One stack's results.
#[derive(Debug, Clone)]
pub struct DecodeStackOutcome {
    pub telemetry: DecodeTelemetry,
    pub peak_c: f64,
    pub reram_peak_c: f64,
    pub throttle_events: u64,
    pub windows: u64,
    /// KV pool bytes still reserved when the stack wound down. Zero for
    /// every healthy run (retirement releases reservations); the fault
    /// layer's leak check pins it at zero even after [`ClusterStack::fail`].
    pub kv_reserved_end_bytes: f64,
    /// KV pool bytes still written when the stack wound down (same
    /// zero-leak contract as `kv_reserved_end_bytes`).
    pub kv_used_end_bytes: f64,
}

/// One finished request, as logged by a stack with completion
/// recording on ([`DecodeStack::record_completions`]). The fleet
/// driver's hand-off source: a prefill-specialized stack serves each
/// request to its first token (`out_tokens` rewritten to 1), and the
/// driver turns the logged completion into a [`KvHandoff`] for a
/// decode-specialized stack.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub model: ModelId,
    pub variant: ArchVariant,
    /// Prompt length (the prefilled, cached context).
    pub prompt: usize,
    pub arrival_s: f64,
    pub first_token_s: f64,
    /// Retirement instant on the serving stack's clock.
    pub finish_s: f64,
}

/// A prefilled request arriving at a decode-specialized stack with its
/// KV cache shipped over the interconnect: the prompt plus first token
/// are already cached elsewhere and become resident here at `ready_s`
/// (prefill finish + wire latency). Joining the running set re-reserves
/// the request's peak KV footprint locally and charges the wire time
/// into the thermal background ([`DecodeStack::push_handoff`]).
#[derive(Debug, Clone)]
pub struct KvHandoff {
    pub id: u64,
    pub model: ModelId,
    pub variant: ArchVariant,
    /// Prompt length whose cache was transferred.
    pub prompt: usize,
    pub arrival_s: f64,
    /// TTFT already happened on the prefill stack; kept so E2E latency
    /// and TPOT stay anchored to the true first token.
    pub first_token_s: f64,
    /// Instant the transferred cache is fully resident here.
    pub ready_s: f64,
    /// Bytes moved over the interconnect (prompt + first token).
    pub kv_bytes: f64,
    /// Wire time the transfer occupied (`kv_bytes` / link bandwidth).
    pub transfer_s: f64,
    /// The *original* output budget. Always ≥ 2: single-token requests
    /// retire at prefill and never hand off (the fleet driver filters).
    pub out_tokens: usize,
}

/// A request mid-generation.
#[derive(Debug, Clone)]
struct ActiveGen {
    /// Originating request id, kept so [`ClusterStack::fail`] can
    /// surrender the generation as a re-routable [`Request`].
    id: u64,
    model: ModelId,
    variant: ArchVariant,
    prompt: usize,
    out_tokens: usize,
    arrival_s: f64,
    /// Output tokens emitted so far (the prefill emits the first).
    generated: usize,
    first_token_s: f64,
    last_token_s: f64,
    /// Peak-footprint reservation held in the KV pool.
    peak_kv: f64,
    /// Bytes actually written so far.
    used_kv: f64,
}

/// A prompt mid-chunking: its first chunks are cached, the rest still
/// to prefill. At most one exists per stack (the chunk lane serves the
/// head of the queue); the peak reservation is held from the first
/// admitted chunk, so the prompt can never be evicted between chunks.
#[derive(Debug, Clone)]
struct PartialPrefill {
    req: Request,
    /// Prompt tokens already prefilled and cached.
    done: usize,
    peak_kv: f64,
    used_kv: f64,
}

fn us(seconds: f64) -> u64 {
    (seconds.max(0.0) * 1e6).round() as u64
}

/// Group the running set per (model, variant) in first-seen order.
fn step_groups(engine: &DecodeEngine, running: &[ActiveGen]) -> Vec<StepGroup> {
    let mut groups: Vec<StepGroup> = Vec::new();
    for a in running {
        let dw = engine.workload(a.model, a.variant);
        let sctx = dw.self_context(a.prompt, a.generated);
        let cctx = if dw.cross { a.prompt } else { 0 };
        match groups
            .iter_mut()
            .find(|g| g.model == a.model && g.variant == a.variant)
        {
            Some(g) => {
                g.b += 1;
                g.sum_self_ctx += sctx;
                g.sum_cross_ctx += cctx;
            }
            None => groups.push(StepGroup {
                model: a.model,
                variant: a.variant,
                b: 1,
                sum_self_ctx: sctx,
                sum_cross_ctx: cctx,
            }),
        }
    }
    groups
}

/// Steady-state busy seconds one control window of the current decode
/// batch contributes — the un-throttleable background the admission
/// controller prices prefills against.
fn decode_background(
    engine: &DecodeEngine,
    running: &[ActiveGen],
    interval_s: f64,
) -> BatchCost {
    if running.is_empty() {
        return BatchCost::zero();
    }
    let groups = step_groups(engine, running);
    let sc = engine.step_cost(&groups);
    let total = (sc.mha_s + sc.ff_s).max(1e-12);
    let frac = groups
        .iter()
        .map(|g| engine.active_frac(g.model, g.variant))
        .fold(0.0f64, f64::max);
    BatchCost {
        sm_s: interval_s * sc.mha_s / total,
        ff_s: interval_s * sc.ff_s / total,
        active_frac: frac,
    }
}

fn retire(
    tel: &mut DecodeTelemetry,
    kv: &mut KvPool,
    log: &mut Option<Vec<Completion>>,
    obs: &Recorder,
    obs_stack: usize,
    a: ActiveGen,
) {
    tel.completed += 1;
    obs.terminal(a.last_token_s, a.id, Some(obs_stack), Outcome::Completed);
    tel.e2e_us.record(us(a.last_token_s - a.arrival_s));
    if a.out_tokens > 1 {
        let tpot = (a.last_token_s - a.first_token_s) / (a.out_tokens - 1) as f64;
        tel.tpot_us.record(us(tpot));
    }
    tel.makespan_s = tel.makespan_s.max(a.last_token_s);
    kv.release(a.peak_kv, a.used_kv);
    if let Some(log) = log {
        log.push(Completion {
            id: a.id,
            model: a.model,
            variant: a.variant,
            prompt: a.prompt,
            arrival_s: a.arrival_s,
            first_token_s: a.first_token_s,
            finish_s: a.last_token_s,
        });
    }
}

/// The routing-time service estimate for one generation request:
/// prefill (both phases) plus the whole decode phase priced at the
/// request's mid-flight context length. This is the demand the stacks'
/// horizon ledgers fold — and the same formula the retired pre-pass
/// router consumed, which the live-JSQ equivalence pin rests on.
pub fn est_service_s(
    engine: &DecodeEngine,
    phases: &HashMap<PhaseKey, PhaseInfo>,
    r: &Request,
) -> f64 {
    let info = phases[&(r.model, r.variant, r.seq)];
    let dw = engine.workload(r.model, r.variant);
    let out = r.out_tokens.max(1);
    let g = StepGroup {
        model: r.model,
        variant: r.variant,
        b: 1,
        sum_self_ctx: dw.self_context(r.seq, out / 2),
        sum_cross_ctx: if dw.cross { r.seq } else { 0 },
    };
    info.mha_s + info.ff_s + engine.step_cost(&[g]).wall_s * out as f64
}

/// Outcome of one scheduling decision ([`DecodeStack::advance`]).
enum Advance {
    /// Something happened (an action ran or the clock moved); keep
    /// stepping.
    Progress,
    /// Stepping must pause: the deadline was reached, or (with no
    /// deadline) the stack is drained, or the op backstop aborted it.
    Stop,
}

/// One stack's resumable continuous-batching engine. Construct with
/// [`DecodeStack::new`], feed arrivals with [`ClusterStack::push`],
/// advance with [`ClusterStack::step_until`], and run out the clock
/// with [`DecodeStack::finish`].
pub struct DecodeStack<'a> {
    cfg: &'a Config,
    dc: &'a DecodeConfig,
    phases: &'a HashMap<PhaseKey, PhaseInfo>,
    engine: &'a DecodeEngine<'a>,
    serve_engine: Engine<'a>,
    state: ServeState,
    kv: KvPool,
    ctl: AdmissionController,
    tel: DecodeTelemetry,
    interval: f64,
    wait: f64,
    max_running: usize,
    /// Routed arrivals the clock has not reached yet (stream order).
    pending: VecDeque<Request>,
    waiting: VecDeque<Request>,
    running: Vec<ActiveGen>,
    /// The chunk lane (chunk_tokens > 0 only): at most one prompt
    /// mid-chunking, and an alternation flag forcing one decode step
    /// between consecutive chunks while generations are running.
    partial: Option<PartialPrefill>,
    chunk_turn: bool,
    t: f64,
    /// Thermal deferral gate: no prefill attempts before this time.
    admit_block_until: f64,
    /// Work already admitted in the current control window (priced as
    /// background so sustained launches accumulate heat).
    window_cost: BatchCost,
    window_end: f64,
    // Decode-phase accumulators for the end-of-run energy model.
    dec_sm_flops: f64,
    dec_ff_ops: f64,
    dec_l2_bytes: f64,
    dec_kv_bytes: f64,
    dec_mha_busy: f64,
    dec_ff_busy: f64,
    /// Simulated control windows elapsed (what `control_windows`
    /// reports; the controller's own counter counts admission
    /// *decisions*).
    sim_windows: u64,
    ops: u64,
    /// Grows with every push so the abort backstop covers exactly the
    /// accepted work (the pre-cluster loop computed it from its whole
    /// shard up front).
    ops_budget: u64,
    done: bool,
    /// Commitment ledger: estimated completion of all accepted work.
    horizon_s: f64,
    /// Peak KV bytes of accepted-but-unlaunched requests — added on
    /// push, moved into the pool at launch, dropped on shed. Committed
    /// bytes = pool reservations + this.
    pending_kv_bytes: f64,
    ewma_ttft_s: f64,
    ewma_itl_s: f64,
    /// Which architecture preset this stack models (snapshot metadata —
    /// the per-arch bench utilization rows key on it).
    arch_id: StackArchId,
    /// Relative decode-throughput scale the routing policies normalize
    /// work terms by (`hetrax3d` = 1.0).
    compute_scale: f64,
    /// O(1) mirrors of the walked snapshot ledgers (the ROADMAP-flagged
    /// hot spot): maintained incrementally at every queue transition,
    /// pinned against [`DecodeStack::walk_outstanding`] /
    /// [`DecodeStack::walk_queue_depth`] by `debug_assert` and a test.
    outstanding: u64,
    depth: usize,
    /// Completion log (the fleet hand-off source); `None` — no logging,
    /// no allocation — outside disaggregated serving.
    completion_log: Option<Vec<Completion>>,
    /// Transferred-KV arrivals not yet joined: cache still in flight
    /// (`ready_s` ahead of the clock) or blocked on slots/pool (FIFO).
    handoffs: VecDeque<KvHandoff>,
    /// Total KV bytes received over the interconnect (energy model).
    xfer_bytes: f64,
    /// Observability handle ([`Recorder::Off`] by default: one enum
    /// discriminant branch per hook, no allocation) and this stack's
    /// trace index ([`DecodeStack::attach_obs`]).
    obs: Recorder,
    obs_stack: usize,
    /// Decode-step counter for [`DECODE_STEP_SAMPLE`] sampling —
    /// advanced only while recording, so the off path stays untouched.
    obs_steps: u64,
}

impl<'a> DecodeStack<'a> {
    pub fn new(
        cfg: &'a Config,
        dc: &'a DecodeConfig,
        phases: &'a HashMap<PhaseKey, PhaseInfo>,
        engine: &'a DecodeEngine<'a>,
    ) -> DecodeStack<'a> {
        let arch = StackArch::preset(StackArchId::Hetrax3d);
        DecodeStack::with_arch(cfg, dc, phases, engine, &arch)
    }

    /// Construct for an explicit architecture preset. The KV budget and
    /// thermal ceiling come from the arch's overrides; `cfg`, `phases`
    /// and `engine` must already be built from
    /// [`StackArch::config`] so phase costs price the right silicon.
    /// `hetrax3d` applies no overrides, so `new` (which delegates here)
    /// stays bit-identical to the pre-fleet constructor.
    pub fn with_arch(
        cfg: &'a Config,
        dc: &'a DecodeConfig,
        phases: &'a HashMap<PhaseKey, PhaseInfo>,
        engine: &'a DecodeEngine<'a>,
        arch: &StackArch,
    ) -> DecodeStack<'a> {
        let interval = dc.throttle.interval_s.max(1e-6);
        let wait = dc.throttle.max_queue_wait_s;
        // Backstop against config pathologies: every iteration either
        // emits tokens, serves a prefill chunk, launches a prefill, or
        // advances the clock, so the budget (grown per accepted
        // request) sits far above any legitimate run.
        let ops_budget =
            4 * ((dc.duration_s + wait) / interval).ceil() as u64 + 1024;
        DecodeStack {
            cfg,
            dc,
            phases,
            engine,
            serve_engine: Engine::new(cfg),
            state: ServeState::new(),
            kv: KvPool::new(arch.kv_config(dc.kv)),
            ctl: AdmissionController::new(cfg, arch.throttle(dc.throttle), dc.max_prefill_batch),
            tel: DecodeTelemetry::new(),
            interval,
            wait,
            max_running: dc.max_running.max(1),
            pending: VecDeque::new(),
            waiting: VecDeque::new(),
            running: Vec::new(),
            partial: None,
            chunk_turn: true,
            t: 0.0,
            admit_block_until: 0.0,
            window_cost: BatchCost::zero(),
            window_end: interval,
            dec_sm_flops: 0.0,
            dec_ff_ops: 0.0,
            dec_l2_bytes: 0.0,
            dec_kv_bytes: 0.0,
            dec_mha_busy: 0.0,
            dec_ff_busy: 0.0,
            sim_windows: 0,
            ops: 0,
            ops_budget,
            done: false,
            horizon_s: 0.0,
            pending_kv_bytes: 0.0,
            ewma_ttft_s: 0.0,
            ewma_itl_s: 0.0,
            arch_id: arch.id,
            compute_scale: arch.compute_scale,
            outstanding: 0,
            depth: 0,
            completion_log: None,
            handoffs: VecDeque::new(),
            xfer_bytes: 0.0,
            obs: Recorder::Off,
            obs_stack: 0,
            obs_steps: 0,
        }
    }

    /// Attach an observability recorder, labelling this stack's trace
    /// track `stack`. Off by default; attaching never changes a
    /// scheduling decision — every hook only reads state the loop
    /// already computed (the recorder-off equivalence tests pin this).
    pub fn attach_obs(&mut self, rec: Recorder, stack: usize) {
        self.obs = rec;
        self.obs_stack = stack;
    }

    fn peak_kv_of(&self, r: &Request) -> f64 {
        self.engine
            .workload(r.model, r.variant)
            .peak_kv_bytes(r.seq, r.out_tokens.max(1))
    }

    fn record_ttft(&mut self, sample_s: f64) {
        self.tel.ttft_us.record(us(sample_s));
        self.ewma_ttft_s =
            cluster::ewma(self.ewma_ttft_s, sample_s, self.tel.ttft_us.count() == 1);
    }

    fn record_itl(&mut self, sample_s: f64) {
        self.tel.itl_us.record(us(sample_s));
        self.ewma_itl_s =
            cluster::ewma(self.ewma_itl_s, sample_s, self.tel.itl_us.count() == 1);
    }

    /// Drain the stack: run every remaining decision to quiescence
    /// without consuming it. The fleet driver uses this to run
    /// prefill-specialized stacks dry, drain their completion logs into
    /// hand-offs, and only then fold outcomes with [`DecodeStack::finish`].
    pub fn run_to_completion(&mut self) {
        while !self.done {
            if let Advance::Stop = self.advance(None) {
                break;
            }
        }
    }

    /// Turn the completion log on or off (the fleet driver enables it
    /// on prefill-specialized stacks). Off by default — no allocation,
    /// no behaviour change.
    pub fn record_completions(&mut self, on: bool) {
        self.completion_log = if on { Some(Vec::new()) } else { None };
    }

    /// Take every completion logged since the last drain (empty when
    /// logging is off).
    pub fn drain_completions(&mut self) -> Vec<Completion> {
        self.completion_log
            .as_mut()
            .map(std::mem::take)
            .unwrap_or_default()
    }

    /// Accept a transferred-KV arrival (disaggregated serving): the
    /// request was prefilled on another stack and its cache is on the
    /// wire, resident here at `h.ready_s`. Counted `submitted` like any
    /// routed arrival — the prefill stack's single-token completion is
    /// the matching exit in its own ledger, so both stacks' double-entry
    /// identities stay exact. Refused at the door if the peak footprint
    /// can never fit this pool (a queued-forever hand-off would wedge
    /// the drain); otherwise it joins the running set through the
    /// step-2b join lane once resident.
    pub fn push_handoff(&mut self, h: KvHandoff) {
        debug_assert!(h.out_tokens > 1, "single-token requests never hand off");
        self.tel.submitted += 1;
        if self.done {
            self.tel.shed += 1;
            self.obs.terminal(self.t, h.id, Some(self.obs_stack), Outcome::Shed);
            return;
        }
        let dw = self.engine.workload(h.model, h.variant);
        let peak = dw.peak_kv_bytes(h.prompt, h.out_tokens);
        if peak > self.kv.capacity_bytes() {
            self.tel.refused_kv += 1;
            self.obs
                .terminal(self.t, h.id, Some(self.obs_stack), Outcome::RefusedKv);
            return;
        }
        // Horizon ledger: the decode remainder priced at mid-flight
        // context (the same arithmetic `est_service_s` charges for the
        // decode phase) plus the wire time.
        let g = StepGroup {
            model: h.model,
            variant: h.variant,
            b: 1,
            sum_self_ctx: dw.self_context(h.prompt, h.out_tokens / 2),
            sum_cross_ctx: if dw.cross { h.prompt } else { 0 },
        };
        let est = self.engine.step_cost(&[g]).wall_s * h.out_tokens as f64
            + h.transfer_s;
        self.horizon_s = self.horizon_s.max(h.ready_s) + est;
        self.pending_kv_bytes += peak;
        self.ops_budget += 4 * (h.out_tokens as u64 + 1);
        self.outstanding += (h.out_tokens - 1) as u64;
        self.depth += 1;
        self.handoffs.push_back(h);
    }

    /// The walking `outstanding_steps` implementation the O(1) counter
    /// mirrors — kept as the oracle: `snapshot()` pins the counter
    /// against it under `debug_assert`, and the counter test walks a
    /// full lifecycle against it.
    pub(crate) fn walk_outstanding(&self) -> u64 {
        let queued: u64 = self
            .waiting
            .iter()
            .chain(self.pending.iter())
            .map(|r| r.out_tokens.max(1) as u64)
            .sum();
        let partial = self
            .partial
            .as_ref()
            .map(|p| p.req.out_tokens.max(1) as u64)
            .unwrap_or(0);
        let running: u64 = self
            .running
            .iter()
            .map(|a| (a.out_tokens - a.generated) as u64)
            .sum();
        let handoff: u64 = self
            .handoffs
            .iter()
            .map(|h| (h.out_tokens - 1) as u64)
            .sum();
        queued + partial + running + handoff
    }

    /// The walking `queue_depth` implementation (see
    /// [`DecodeStack::walk_outstanding`]).
    pub(crate) fn walk_queue_depth(&self) -> usize {
        self.waiting.len()
            + self.pending.len()
            + self.partial.is_some() as usize
            + self.handoffs.len()
    }

    /// Run the stack to completion and extract its outcome. (The
    /// cluster calls this once the arrival stream is exhausted.)
    pub fn finish(mut self) -> DecodeStackOutcome {
        self.run_to_completion();
        // Decode-phase energy (prefill energy came through
        // serve_batch): SM + ReRAM dynamic/static over their busy
        // windows, L2 traffic, the DRAM-side KV stream, and the
        // interconnect flits of any KV transfers received. Skipped for
        // a stack that never saw a request, as the pre-cluster path
        // returned before the fold.
        if self.tel.submitted > 0 {
            self.tel.energy_j +=
                power::sm_energy_j(self.cfg, self.dec_sm_flops, self.dec_mha_busy, 1.0)
                    + power::reram_energy_j(self.cfg, self.dec_ff_ops, self.dec_ff_busy)
                    + power::mc_energy_j(self.cfg, self.dec_l2_bytes, self.dec_mha_busy)
                    + power::dram_energy_j(self.dec_kv_bytes)
                    + fleet::transfer_energy_j(self.xfer_bytes);
        }
        DecodeStackOutcome {
            telemetry: self.tel,
            peak_c: self.ctl.peak_c,
            reram_peak_c: self.ctl.reram_peak_c,
            throttle_events: self.ctl.events.len() as u64,
            windows: self.sim_windows,
            kv_reserved_end_bytes: self.kv.reserved_bytes(),
            kv_used_end_bytes: self.kv.used_bytes(),
        }
    }

    /// One scheduling decision at the current clock. With a deadline,
    /// idle jumps clamp to it (the cluster regains control there);
    /// without one, a fully drained stack marks itself done.
    fn advance(&mut self, deadline: Option<f64>) -> Advance {
        // Window bookkeeping on the simulated clock (O(1) even across
        // long idle jumps; the while is a float-rounding backstop).
        if self.t >= self.window_end {
            // Close the window's thermal book first: decode-heavy
            // stretches make no admission calls, so the committed
            // running batch plus this window's admitted work is
            // recorded here.
            let mut closing = decode_background(self.engine, &self.running, self.interval);
            closing.add(&self.window_cost);
            self.ctl.observe(&closing);
            if self.obs.enabled() {
                // Gauges at the close of window `sim_windows`, stamped
                // at its scheduled end (idle jumps skip the windows in
                // between — they carry no new information).
                self.obs.window(
                    self.window_end,
                    self.obs_stack,
                    self.sim_windows,
                    WindowSample {
                        reram_c: self.ctl.last_reram_c,
                        batch_cap: self.ctl.batch_cap,
                        emergency: self.ctl.in_emergency(),
                        queue_depth: self.depth,
                        outstanding_steps: self.outstanding,
                        kv_committed_bytes: self.kv.reserved_bytes()
                            + self.pending_kv_bytes,
                    },
                );
            }
            let mut k = ((self.t - self.window_end) / self.interval).floor() as u64 + 1;
            self.window_end += k as f64 * self.interval;
            while self.t >= self.window_end {
                self.window_end += self.interval;
                k += 1;
            }
            self.sim_windows += k;
            self.window_cost = BatchCost::zero();
        }

        // 1. Ingest arrivals due by now; refuse outright what can never
        //    fit the stack's cache budget.
        while let Some(front) = self.pending.front() {
            if front.arrival_s > self.t {
                break;
            }
            let r = self.pending.pop_front().expect("front just checked");
            if self.peak_kv_of(&r) > self.kv.capacity_bytes() {
                self.tel.refused_kv += 1;
                self.obs
                    .terminal(self.t, r.id, Some(self.obs_stack), Outcome::RefusedKv);
                self.outstanding -= r.out_tokens.max(1) as u64;
                self.depth -= 1;
            } else {
                self.waiting.push_back(r);
            }
        }

        // 2. Age out waiting requests past the queue bound (their
        //    ledgered peaks leave the committed total with them).
        let before = self.waiting.len();
        let (t, wait) = (self.t, self.wait);
        let engine = self.engine;
        let record = self.obs.enabled();
        let mut shed_kv = 0.0f64;
        let mut shed_steps = 0u64;
        let mut shed_ids: Vec<u64> = Vec::new();
        self.waiting.retain(|r| {
            if t - r.arrival_s <= wait {
                true
            } else {
                shed_kv += engine
                    .workload(r.model, r.variant)
                    .peak_kv_bytes(r.seq, r.out_tokens.max(1));
                shed_steps += r.out_tokens.max(1) as u64;
                if record {
                    shed_ids.push(r.id);
                }
                false
            }
        });
        self.tel.shed += (before - self.waiting.len()) as u64;
        for id in shed_ids {
            self.obs.terminal(t, id, Some(self.obs_stack), Outcome::Shed);
        }
        self.pending_kv_bytes = (self.pending_kv_bytes - shed_kv).max(0.0);
        self.outstanding -= shed_steps;
        self.depth -= before - self.waiting.len();

        // 2b. Join transferred-KV hand-offs (disaggregated serving
        //     only; FIFO). A hand-off joins once its cache is resident
        //     (`ready_s` reached), a running slot is free, and the pool
        //     takes its peak reservation. It enters the running set at
        //     `generated = 1` — the first token was emitted by the
        //     prefill stack — so the first local decode step's ITL gap
        //     absorbs queueing plus the wire delay, and the wire time
        //     is charged into this window's thermal book.
        while let Some(h) = self.handoffs.front() {
            if h.ready_s > self.t || self.running.len() >= self.max_running {
                break;
            }
            let dw = self.engine.workload(h.model, h.variant);
            let peak = dw.peak_kv_bytes(h.prompt, h.out_tokens);
            if !self.kv.try_reserve(peak) {
                break;
            }
            let h = self.handoffs.pop_front().expect("front just checked");
            self.obs.handoff_join(self.t, self.obs_stack, h.id);
            self.pending_kv_bytes = (self.pending_kv_bytes - peak).max(0.0);
            let used = dw.kv_bytes(h.prompt, 1);
            self.kv.grow(used);
            self.xfer_bytes += h.kv_bytes;
            self.window_cost.add(&BatchCost {
                sm_s: h.transfer_s,
                ff_s: 0.0,
                active_frac: 0.0,
            });
            self.depth -= 1;
            self.running.push(ActiveGen {
                id: h.id,
                model: h.model,
                variant: h.variant,
                prompt: h.prompt,
                out_tokens: h.out_tokens,
                arrival_s: h.arrival_s,
                generated: 1,
                first_token_s: h.first_token_s,
                last_token_s: h.first_token_s,
                peak_kv: peak,
                used_kv: used,
            });
            self.tel.peak_running =
                self.tel.peak_running.max(self.running.len() as u64);
            self.tel.peak_kv_bytes =
                self.tel.peak_kv_bytes.max(self.kv.used_bytes());
        }

        // 3. Advance prefill work. The chunk lane (chunking only) takes
        //    precedence: it continues the in-flight partial prompt, or
        //    promotes the head of the queue when its prompt exceeds the
        //    budget. Otherwise one whole prefill batch may launch —
        //    token-budget-capped when chunking is on, exactly the
        //    pre-chunking path when it is off.
        let mut launched = false;
        let chunking = self.dc.chunk_tokens > 0;
        if chunking
            && self.t >= self.admit_block_until
            && (self.running.is_empty() || self.chunk_turn)
        {
            // Pick the chunk job: the partial already holding its
            // reservation, else the un-popped queue head (it stays
            // ageable in `waiting` until its first chunk is admitted).
            let job: Option<(Request, usize, f64, f64)> = match self.partial.take() {
                Some(p) => Some((p.req, p.done, p.peak_kv, p.used_kv)),
                None if self.running.len() < self.max_running
                    && !self.waiting.is_empty()
                    && self.waiting[0].seq > self.dc.chunk_tokens =>
                {
                    let r = &self.waiting[0];
                    let peak = self.peak_kv_of(r);
                    if self.kv.would_fit(peak) {
                        Some((r.clone(), 0, peak, 0.0))
                    } else {
                        None
                    }
                }
                None => None,
            };
            if let Some((req, mut done, peak_kv, mut used_kv)) = job {
                let c = self.dc.chunk_tokens.min(req.seq - done);
                let mut chunk_req = req.clone();
                chunk_req.seq = c;
                let batch = Batch { requests: vec![chunk_req], ready_s: self.t };
                let info = self.phases[&(req.model, req.variant, c)];
                let surcharge =
                    self.engine.chunk_attn_cost(req.model, req.variant, c, done);
                let cost = BatchCost {
                    sm_s: info.mha_s + surcharge.mha_s,
                    ff_s: info.ff_s,
                    active_frac: info.active_frac,
                };
                let mut background =
                    decode_background(self.engine, &self.running, self.interval);
                background.add(&self.window_cost);
                let (admitted, _deferred) = self.ctl.admit_with_background(
                    self.t,
                    vec![batch],
                    &[cost],
                    background,
                );
                if let Some(batch) = admitted.into_iter().next() {
                    if done == 0 {
                        // First chunk: the prompt commits — leave the
                        // queue, hold the peak reservation to EOS.
                        self.waiting.pop_front();
                        self.pending_kv_bytes =
                            (self.pending_kv_bytes - peak_kv).max(0.0);
                        let ok = self.kv.try_reserve(peak_kv);
                        debug_assert!(ok, "reservation was pre-checked");
                    }
                    let span_start = self.t;
                    let out = self
                        .serve_engine
                        .serve_batch(&mut self.state, &batch)
                        .expect("chunk batch is non-empty");
                    // The prior-prefix attention runs on the SM tiers
                    // right after the chunk's own phases.
                    let end = out.finish_s + surcharge.mha_s;
                    self.state.sm_free = self.state.sm_free.max(end);
                    self.t = end;
                    self.obs
                        .prefill(self.obs_stack, req.id, span_start, end, c, true);
                    self.window_cost.add(&cost);
                    self.tel.prefill_chunks += 1;
                    self.tel.sm_busy_s += out.sm_busy_s + surcharge.mha_s;
                    self.tel.reram_busy_s += out.reram_busy_s;
                    self.tel.energy_j += out.energy_j;
                    self.dec_mha_busy += surcharge.mha_s;
                    self.dec_sm_flops += surcharge.sm_flops;
                    self.dec_kv_bytes += surcharge.kv_read_bytes;
                    let dw = self.engine.workload(req.model, req.variant);
                    let grow = dw.kv_bytes(done + c, 0) - dw.kv_bytes(done, 0);
                    self.kv.grow(grow);
                    used_kv += grow;
                    done += c;
                    if done >= req.seq {
                        // Prompt complete: the prefill emits the first
                        // token, exactly like the whole-batch path.
                        let first = dw.kv_bytes(req.seq, 1) - dw.kv_bytes(req.seq, 0);
                        self.kv.grow(first);
                        used_kv += first;
                        let out_tokens = req.out_tokens.max(1);
                        self.tel.prefill_batches += 1;
                        self.tel.tokens_out += 1;
                        let sample = self.t - req.arrival_s;
                        self.record_ttft(sample);
                        let a = ActiveGen {
                            id: req.id,
                            model: req.model,
                            variant: req.variant,
                            prompt: req.seq,
                            out_tokens,
                            arrival_s: req.arrival_s,
                            generated: 1,
                            first_token_s: self.t,
                            last_token_s: self.t,
                            peak_kv,
                            used_kv,
                        };
                        // The prompt leaves the queue ledgers: its
                        // queued `out` steps become a running `out - 1`
                        // remainder (or retire outright at out == 1).
                        self.outstanding -= 1;
                        self.depth -= 1;
                        if a.generated >= a.out_tokens {
                            retire(
                                &mut self.tel,
                                &mut self.kv,
                                &mut self.completion_log,
                                &self.obs,
                                self.obs_stack,
                                a,
                            );
                        } else {
                            self.running.push(a);
                        }
                        self.tel.peak_running =
                            self.tel.peak_running.max(self.running.len() as u64);
                    } else {
                        self.partial =
                            Some(PartialPrefill { req, done, peak_kv, used_kv });
                    }
                    self.tel.peak_kv_bytes =
                        self.tel.peak_kv_bytes.max(self.kv.used_bytes());
                    self.chunk_turn = false;
                    launched = true;
                } else {
                    // Thermally deferred: hold the chunk lane for the
                    // rest of this control window; an in-flight partial
                    // keeps its reservation, an unpromoted head stays
                    // queued (and ageable).
                    self.admit_block_until = self.window_end;
                    if done > 0 {
                        self.partial =
                            Some(PartialPrefill { req, done, peak_kv, used_kv });
                    }
                }
            }
        }

        // Whole-batch prefill launch (continuous-batching join). Blocked
        // while a partial prompt owns the chunk lane; with chunking on,
        // a long head prompt is chunk-lane work, never a whole batch,
        // and whole batches obey the same chunk/decode alternation —
        // otherwise a queue of short prompts would launch budget-sized
        // batches back to back and stack stalls the budget exists to
        // bound.
        let room = self.max_running.saturating_sub(self.running.len());
        if !launched
            && self.partial.is_none()
            && room > 0
            && !self.waiting.is_empty()
            && self.t >= self.admit_block_until
            && (!chunking || self.waiting[0].seq <= self.dc.chunk_tokens)
            && (!chunking || self.running.is_empty() || self.chunk_turn)
        {
            let head = (self.waiting[0].model, self.waiting[0].variant);
            let cap = room
                .min(self.dc.max_prefill_batch)
                .min(self.ctl.batch_cap)
                .max(1);
            let mut cand = 0usize;
            let mut kv_need = 0.0f64;
            let mut tok_need = 0usize;
            for r in self.waiting.iter() {
                if cand >= cap || (r.model, r.variant) != head {
                    break;
                }
                if chunking && cand > 0 && tok_need + r.seq > self.dc.chunk_tokens {
                    break;
                }
                let peak = self
                    .engine
                    .workload(r.model, r.variant)
                    .peak_kv_bytes(r.seq, r.out_tokens.max(1));
                if !self.kv.would_fit(kv_need + peak) {
                    break;
                }
                kv_need += peak;
                tok_need += r.seq;
                cand += 1;
            }
            if cand > 0 {
                let batch = Batch {
                    requests: self.waiting.iter().take(cand).cloned().collect(),
                    ready_s: self.t,
                };
                let info = self.phases[&(head.0, head.1, batch.seq())];
                let n = cand as f64;
                let cost = BatchCost {
                    sm_s: info.mha_s * n,
                    ff_s: info.ff_s * n,
                    active_frac: info.active_frac,
                };
                let mut background =
                    decode_background(self.engine, &self.running, self.interval);
                background.add(&self.window_cost);
                let (admitted, _deferred) = self.ctl.admit_with_background(
                    self.t,
                    vec![batch],
                    &[cost],
                    background,
                );
                if let Some(batch) = admitted.into_iter().next() {
                    let span_start = self.t;
                    let out = self
                        .serve_engine
                        .serve_batch(&mut self.state, &batch)
                        .expect("prefill batch is non-empty");
                    self.window_cost.add(&cost);
                    self.tel.prefill_batches += 1;
                    self.tel.sm_busy_s += out.sm_busy_s;
                    self.tel.reram_busy_s += out.reram_busy_s;
                    self.tel.energy_j += out.energy_j;
                    self.t = out.finish_s;
                    for r in self.waiting.drain(..cand).collect::<Vec<_>>() {
                        let dw = self.engine.workload(r.model, r.variant);
                        let out_tokens = r.out_tokens.max(1);
                        let peak = dw.peak_kv_bytes(r.seq, out_tokens);
                        self.pending_kv_bytes =
                            (self.pending_kv_bytes - peak).max(0.0);
                        let ok = self.kv.try_reserve(peak);
                        debug_assert!(ok, "reservation was pre-checked");
                        let used = dw.kv_bytes(r.seq, 1);
                        self.kv.grow(used);
                        self.tel.tokens_out += 1;
                        let sample = self.t - r.arrival_s;
                        self.record_ttft(sample);
                        self.obs.prefill(
                            self.obs_stack,
                            r.id,
                            span_start,
                            self.t,
                            r.seq,
                            false,
                        );
                        let a = ActiveGen {
                            id: r.id,
                            model: r.model,
                            variant: r.variant,
                            prompt: r.seq,
                            out_tokens,
                            arrival_s: r.arrival_s,
                            generated: 1,
                            first_token_s: self.t,
                            last_token_s: self.t,
                            peak_kv: peak,
                            used_kv: used,
                        };
                        self.outstanding -= 1;
                        self.depth -= 1;
                        if a.generated >= a.out_tokens {
                            retire(
                                &mut self.tel,
                                &mut self.kv,
                                &mut self.completion_log,
                                &self.obs,
                                self.obs_stack,
                                a,
                            );
                        } else {
                            self.running.push(a);
                        }
                    }
                    self.tel.peak_running =
                        self.tel.peak_running.max(self.running.len() as u64);
                    self.tel.peak_kv_bytes =
                        self.tel.peak_kv_bytes.max(self.kv.used_bytes());
                    if chunking {
                        self.chunk_turn = false;
                    }
                    launched = true;
                } else {
                    // Thermally deferred: hold admissions for the rest
                    // of this control window.
                    self.admit_block_until = self.window_end;
                }
            }
        }

        if !launched && !self.running.is_empty() {
            // 4. One decode step over the whole running set.
            let groups = step_groups(self.engine, &self.running);
            let sc = self.engine.step_cost(&groups);
            let start = self.t;
            let end = start + sc.wall_s;
            self.state.sm_free = self.state.sm_free.max(start + sc.mha_s);
            self.state.reram_free = self.state.reram_free.max(end);
            self.t = end;
            self.tel.decode_steps += 1;
            self.tel.sm_busy_s += sc.mha_s;
            self.tel.reram_busy_s += sc.ff_s;
            self.dec_mha_busy += sc.mha_s;
            self.dec_ff_busy += sc.ff_s;
            self.dec_sm_flops += sc.sm_flops;
            self.dec_ff_ops += sc.ff_ops;
            self.dec_l2_bytes += sc.l2_bytes;
            self.dec_kv_bytes += sc.kv_read_bytes;
            if self.obs.enabled() {
                // Sampled: the first step of every DECODE_STEP_SAMPLE
                // stride (so short generations still leave a mark).
                self.obs_steps += 1;
                if self.obs_steps % DECODE_STEP_SAMPLE == 1 {
                    self.obs
                        .decode_step(self.obs_stack, start, end, self.running.len());
                }
            }

            // Every running generation's remaining-step count drops by
            // one; retirements below remove zero-remainder entries.
            self.outstanding -= self.running.len() as u64;
            let mut i = 0;
            while i < self.running.len() {
                let (gap, model, variant) = {
                    let a = &mut self.running[i];
                    a.generated += 1;
                    let gap = end - a.last_token_s;
                    a.last_token_s = end;
                    (gap, a.model, a.variant)
                };
                self.record_itl(gap);
                let grow = self.engine.workload(model, variant).kv_bytes_per_token();
                self.kv.grow(grow);
                self.running[i].used_kv += grow;
                self.tel.tokens_out += 1;
                if self.running[i].generated >= self.running[i].out_tokens {
                    let done = self.running.remove(i);
                    retire(
                        &mut self.tel,
                        &mut self.kv,
                        &mut self.completion_log,
                        &self.obs,
                        self.obs_stack,
                        done,
                    );
                } else {
                    i += 1;
                }
            }
            self.tel
                .kv_used_kib
                .record((self.kv.used_bytes() / 1024.0).round() as u64);
            self.tel.peak_kv_bytes = self.tel.peak_kv_bytes.max(self.kv.used_bytes());
            self.chunk_turn = true;
            launched = true;
        }

        if !launched {
            // 5. Idle: advance to the next meaningful instant (clamped
            //    to the cluster's deadline, where control returns so an
            //    arrival at that instant is visible before the next
            //    decision — exactly the pre-cluster ingest order).
            let pending_work = self.partial.is_some() || !self.waiting.is_empty();
            if pending_work && self.t < self.admit_block_until {
                match deadline {
                    Some(d) if self.admit_block_until > d => {
                        self.t = d;
                        return Advance::Stop;
                    }
                    _ => self.t = self.admit_block_until,
                }
            } else if !pending_work
                && (!self.pending.is_empty() || !self.handoffs.is_empty())
            {
                // Jump to the next routed arrival or hand-off residency
                // (both strictly ahead of the clock — ingest and the
                // join lane above drained everything due; a queued
                // hand-off here is still on the wire, since with the
                // running set empty nothing blocks a resident one),
                // clamped to the deadline: the trait contract promises
                // never to advance past it, even for a caller that
                // pushes arrivals further ahead than the cluster does.
                let next_arrival = self
                    .pending
                    .front()
                    .map(|r| r.arrival_s)
                    .unwrap_or(f64::INFINITY);
                let next_ready = self
                    .handoffs
                    .front()
                    .map(|h| h.ready_s)
                    .unwrap_or(f64::INFINITY);
                let next = next_arrival.min(next_ready);
                match deadline {
                    Some(d) if next > d => {
                        self.t = self.t.max(d);
                        return Advance::Stop;
                    }
                    _ => self.t = next,
                }
            } else if !pending_work {
                match deadline {
                    Some(d) => {
                        self.t = self.t.max(d);
                        return Advance::Stop;
                    }
                    None => {
                        self.done = true;
                        return Advance::Stop;
                    }
                }
            } else {
                // Defensive: pending prefill work unlaunchable with an
                // empty pool cannot happen (refusal is checked at
                // ingest, partial reservations are pre-checked), but
                // never spin — shed it and move on.
                if let Some(p) = self.partial.take() {
                    self.kv.release(p.peak_kv, p.used_kv);
                    self.outstanding -= p.req.out_tokens.max(1) as u64;
                    self.depth -= 1;
                } else if let Some(r) = self.waiting.pop_front() {
                    let peak = self.peak_kv_of(&r);
                    self.pending_kv_bytes = (self.pending_kv_bytes - peak).max(0.0);
                    self.outstanding -= r.out_tokens.max(1) as u64;
                    self.depth -= 1;
                }
                self.tel.shed += 1;
            }
        }

        self.ops += 1;
        if self.ops >= self.ops_budget {
            if self.obs.enabled() {
                // Terminal per aborted owner, in the same order the
                // shed sum below counts them.
                let (t, stack) = (self.t, self.obs_stack);
                for r in self.waiting.iter() {
                    self.obs.terminal(t, r.id, Some(stack), Outcome::Shed);
                }
                for a in self.running.iter() {
                    self.obs.terminal(t, a.id, Some(stack), Outcome::Shed);
                }
                if let Some(p) = &self.partial {
                    self.obs.terminal(t, p.req.id, Some(stack), Outcome::Shed);
                }
                for r in self.pending.iter() {
                    self.obs.terminal(t, r.id, Some(stack), Outcome::Shed);
                }
                for h in self.handoffs.iter() {
                    self.obs.terminal(t, h.id, Some(stack), Outcome::Shed);
                }
            }
            // Conservation even on abort: un-ingested arrivals count as
            // shed too, so completed + shed + refused_kv == submitted.
            self.tel.shed += self.waiting.len() as u64
                + self.running.len() as u64
                + self.partial.is_some() as u64
                + self.pending.len() as u64
                + self.handoffs.len() as u64;
            for a in self.running.drain(..) {
                self.kv.release(a.peak_kv, a.used_kv);
            }
            if let Some(p) = self.partial.take() {
                self.kv.release(p.peak_kv, p.used_kv);
            }
            self.waiting.clear();
            self.pending.clear();
            self.handoffs.clear();
            self.pending_kv_bytes = 0.0;
            self.outstanding = 0;
            self.depth = 0;
            self.done = true;
            return Advance::Stop;
        }
        Advance::Progress
    }
}

impl ClusterStack for DecodeStack<'_> {
    fn step_until(&mut self, deadline_s: f64) {
        // Strict `<`: a decision at exactly the deadline waits for the
        // arrival at that instant to be routed first.
        while !self.done && self.t < deadline_s {
            if let Advance::Stop = self.advance(Some(deadline_s)) {
                break;
            }
        }
    }

    fn next_event_s(&self) -> f64 {
        // Wakeup bound for the indexed cluster stepper: never later than
        // the next instant this stack's routing-visible state (snapshot
        // fields, completion counters) can change. Earlier is always
        // safe — the stack just steps and finds nothing due.
        if self.done {
            return f64::INFINITY;
        }
        if !self.running.is_empty() {
            // Generations in flight: windows launch back-to-back from
            // `self.t`, so the stack is always due.
            return self.t;
        }
        let next_arrival = self
            .pending
            .front()
            .map_or(f64::INFINITY, |r| r.arrival_s);
        let next_handoff = self
            .handoffs
            .front()
            .map_or(f64::INFINITY, |h| h.ready_s);
        let pending_work = self.partial.is_some() || !self.waiting.is_empty();
        if pending_work {
            if self.t < self.admit_block_until {
                // Thermally blocked: nothing changes until the block
                // lifts, new work lands, or a waiting request ages past
                // the queue-wait bound and sheds.
                let ageout = self
                    .waiting
                    .front()
                    .map_or(f64::INFINITY, |r| r.arrival_s + self.wait);
                self.admit_block_until
                    .min(next_handoff)
                    .min(next_arrival)
                    .min(ageout)
            } else {
                // Launchable work (or the defensive shed path): due now.
                self.t
            }
        } else {
            // Fully idle: asleep until the next routed arrival becomes
            // ingestible or a hand-off finishes its wire residency.
            next_arrival.min(next_handoff)
        }
    }

    fn snapshot(&self, stack: usize) -> StackSnapshot {
        // O(1): the incremental counters replace the per-decision queue
        // walk (the ROADMAP hot spot); the walking oracles stay as the
        // debug-build invariant.
        debug_assert_eq!(self.outstanding, self.walk_outstanding());
        debug_assert_eq!(self.depth, self.walk_queue_depth());
        StackSnapshot {
            stack,
            horizon_s: self.horizon_s,
            queue_depth: self.depth,
            running: self.running.len(),
            slots: self.max_running,
            outstanding_steps: self.outstanding,
            kv_committed_bytes: self.kv.reserved_bytes() + self.pending_kv_bytes,
            kv_capacity_bytes: self.kv.capacity_bytes(),
            reram_c: self.ctl.last_reram_c,
            ewma_ttft_s: self.ewma_ttft_s,
            ewma_itl_s: self.ewma_itl_s,
            health: HealthState::Healthy,
            arch: self.arch_id,
            compute_scale: self.compute_scale,
        }
    }

    fn push(&mut self, req: Request) {
        self.tel.submitted += 1;
        if self.done {
            // The ops backstop already aborted this stack: it will
            // never serve again, so count the arrival as shed on the
            // spot — conservation (completed + shed + refused_kv ==
            // submitted) survives even the pathological abort path.
            self.tel.shed += 1;
            self.obs.terminal(self.t, req.id, Some(self.obs_stack), Outcome::Shed);
            return;
        }
        let est = est_service_s(self.engine, self.phases, &req);
        self.horizon_s = self.horizon_s.max(req.arrival_s) + est;
        let peak = self.peak_kv_of(&req);
        if peak <= self.kv.capacity_bytes() {
            // Oversized requests are refused at ingest and never charge
            // the committed ledger — the same convention the policies
            // use.
            self.pending_kv_bytes += peak;
        }
        let chunks = if self.dc.chunk_tokens > 0 {
            req.seq.div_ceil(self.dc.chunk_tokens) as u64
        } else {
            0
        };
        self.ops_budget += 4 * (req.out_tokens.max(1) as u64 + chunks + 1);
        // The counters mirror the walking ledgers exactly: an oversized
        // request still counts while pending (the walk counts it too);
        // the refusal at ingest takes it back out.
        self.outstanding += req.out_tokens.max(1) as u64;
        self.depth += 1;
        self.pending.push_back(req);
    }

    /// Abort the stack for the fault layer: every request it still owns
    /// — un-ingested, queued, mid-generation, mid-chunking — is counted
    /// shed here (double-entry: the failover driver re-submits the
    /// survivors elsewhere) and returned for re-routing with its KV
    /// reservation released. Mid-flight generations lose their cached
    /// context, so their surrendered [`Request`] carries `input: None`
    /// — the retry pays the full prefill-recompute cost.
    fn fail(&mut self, t_s: f64) -> Vec<Request> {
        let mut surrendered: Vec<Request> = Vec::new();
        surrendered.extend(self.pending.drain(..));
        surrendered.extend(self.waiting.drain(..));
        // In-flight hand-offs surrender too: their transferred cache
        // never landed (or dies with the stack), so — like mid-flight
        // generations — the retry pays the full prefill recompute.
        for h in self.handoffs.drain(..) {
            surrendered.push(Request {
                id: h.id,
                model: h.model,
                variant: h.variant,
                seq: h.prompt,
                arrival_s: h.arrival_s,
                out_tokens: h.out_tokens,
                input: None,
            });
        }
        for a in self.running.drain(..) {
            self.kv.release(a.peak_kv, a.used_kv);
            surrendered.push(Request {
                id: a.id,
                model: a.model,
                variant: a.variant,
                seq: a.prompt,
                arrival_s: a.arrival_s,
                out_tokens: a.out_tokens,
                input: None,
            });
        }
        if let Some(p) = self.partial.take() {
            self.kv.release(p.peak_kv, p.used_kv);
            let mut req = p.req;
            req.input = None;
            surrendered.push(req);
        }
        self.tel.shed += surrendered.len() as u64;
        if self.obs.enabled() {
            // Double-entry with the failover driver: each surrendered
            // request sheds here and re-opens wherever the retry lands.
            for r in &surrendered {
                self.obs.terminal(t_s, r.id, Some(self.obs_stack), Outcome::Shed);
            }
        }
        self.pending_kv_bytes = 0.0;
        self.outstanding = 0;
        self.depth = 0;
        self.done = true;
        surrendered
    }

    fn completed(&self) -> u64 {
        self.tel.completed
    }

    fn set_emergency(&mut self, on: bool) {
        if on {
            self.ctl.enter_emergency();
        } else {
            self.ctl.exit_emergency();
        }
    }
}

/// Run one stack's decode loop over a complete (arrival-sorted) shard:
/// the pre-cluster serial path, kept as the equivalence oracle and for
/// single-shard callers. Byte-identical to driving the same shard
/// through the cluster stepper (pinned by tests in `decodetest`).
pub(crate) fn serve_stack(
    cfg: &Config,
    dc: &DecodeConfig,
    phases: &HashMap<PhaseKey, PhaseInfo>,
    engine: &DecodeEngine,
    reqs: &[Request],
) -> DecodeStackOutcome {
    let mut stack = DecodeStack::new(cfg, dc, phases, engine);
    for r in reqs {
        stack.push(r.clone());
    }
    stack.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traffic::phases;

    fn run_one(reqs: Vec<Request>, dc: &DecodeConfig) -> DecodeStackOutcome {
        let cfg = Config::default();
        let table = phases::phase_table_with_chunks(&cfg, &reqs, dc.chunk_tokens, 1);
        let keys = phases::decode_keys(&reqs);
        let engine = DecodeEngine::build(&cfg, &keys);
        serve_stack(&cfg, dc, &table, &engine, &reqs)
    }

    fn gen_req(id: u64, arrival: f64, prompt: usize, out: usize) -> Request {
        let mut r = Request::synthetic(id, ModelId::BertBase, prompt, arrival);
        r.out_tokens = out;
        r
    }

    fn base_config() -> DecodeConfig {
        DecodeConfig::new(
            ArrivalPattern::Poisson { rps: 0.0 },
            RequestMix::single(ModelId::BertBase),
        )
    }

    #[test]
    fn single_request_lifecycle() {
        let dc = base_config();
        let out = run_one(vec![gen_req(0, 0.0, 128, 5)], &dc);
        let t = &out.telemetry;
        assert_eq!(t.submitted, 1);
        assert_eq!(t.completed, 1);
        assert_eq!(t.shed + t.refused_kv, 0);
        assert_eq!(t.tokens_out, 5);
        assert_eq!(t.prefill_batches, 1);
        assert_eq!(t.decode_steps, 4, "first token from prefill, 4 stepped");
        assert_eq!(t.itl_us.count(), 4);
        assert_eq!(t.ttft_us.count(), 1);
        assert_eq!(t.tpot_us.count(), 1);
        assert!(t.ttft_us.max() > 0, "prefill takes simulated time");
        assert!(t.makespan_s > 0.0);
        assert!(t.peak_kv_bytes > 0.0);
        assert!(t.sm_busy_s > 0.0 && t.reram_busy_s > 0.0);
        assert!(t.energy_j > 0.0);
    }

    #[test]
    fn one_token_request_retires_at_prefill() {
        let dc = base_config();
        let out = run_one(vec![gen_req(0, 0.0, 64, 1)], &dc);
        let t = &out.telemetry;
        assert_eq!(t.completed, 1);
        assert_eq!(t.tokens_out, 1);
        assert_eq!(t.decode_steps, 0);
        assert_eq!(t.itl_us.count(), 0);
        assert_eq!(t.tpot_us.count(), 0, "TPOT undefined for 1-token outputs");
        assert_eq!(t.e2e_us.count(), 1);
    }

    #[test]
    fn later_arrival_joins_running_batch() {
        // Second request arrives mid-generation of the first: it must
        // join (peak_running = 2) rather than wait for completion.
        let dc = base_config();
        let out = run_one(
            vec![gen_req(0, 0.0, 128, 200), gen_req(1, 0.002, 128, 200)],
            &dc,
        );
        let t = &out.telemetry;
        assert_eq!(t.completed, 2);
        assert_eq!(t.peak_running, 2, "continuous batching must join");
        assert_eq!(t.tokens_out, 400);
    }

    #[test]
    fn serial_mode_never_overlaps_requests() {
        let mut dc = base_config();
        dc.max_running = 1;
        let out = run_one(
            vec![gen_req(0, 0.0, 128, 50), gen_req(1, 0.0, 128, 50)],
            &dc,
        );
        let t = &out.telemetry;
        assert_eq!(t.completed, 2);
        assert_eq!(t.peak_running, 1);
        assert_eq!(t.prefill_batches, 2, "one at a time");
    }

    #[test]
    fn step_until_is_equivalent_to_upfront_pushes() {
        // The resumable surface's contract in isolation: pushing at
        // arrival instants with deadline stepping in between must land
        // on the same outcome as pushing the whole shard up front.
        let cfg = Config::default();
        let dc = base_config();
        let reqs = vec![
            gen_req(0, 0.0, 128, 30),
            gen_req(1, 0.004, 64, 8),
            gen_req(2, 0.011, 128, 3),
            gen_req(3, 0.25, 64, 5),
        ];
        let table = phases::phase_table_with_chunks(&cfg, &reqs, dc.chunk_tokens, 1);
        let keys = phases::decode_keys(&reqs);
        let engine = DecodeEngine::build(&cfg, &keys);

        let upfront = serve_stack(&cfg, &dc, &table, &engine, &reqs);

        let mut stepped = DecodeStack::new(&cfg, &dc, &table, &engine);
        for r in &reqs {
            stepped.step_until(r.arrival_s);
            stepped.push(r.clone());
        }
        let stepped = stepped.finish();

        let (a, b) = (&upfront.telemetry, &stepped.telemetry);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.tokens_out, b.tokens_out);
        assert_eq!(a.decode_steps, b.decode_steps);
        assert_eq!(a.prefill_batches, b.prefill_batches);
        assert_eq!(a.ttft_us.percentile(99.0), b.ttft_us.percentile(99.0));
        assert_eq!(a.itl_us.percentile(99.0), b.itl_us.percentile(99.0));
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(upfront.windows, stepped.windows);
        assert_eq!(upfront.reram_peak_c, stepped.reram_peak_c);
    }

    #[test]
    fn snapshot_tracks_ledgers_live() {
        let cfg = Config::default();
        let dc = base_config();
        let reqs = vec![gen_req(0, 0.0, 128, 10), gen_req(1, 0.0, 128, 6)];
        let table = phases::phase_table_with_chunks(&cfg, &reqs, 0, 1);
        let keys = phases::decode_keys(&reqs);
        let engine = DecodeEngine::build(&cfg, &keys);
        let mut stack = DecodeStack::new(&cfg, &dc, &table, &engine);

        let s0 = stack.snapshot(0);
        assert_eq!(s0.queue_depth, 0);
        assert_eq!(s0.kv_committed_bytes, 0.0);
        assert_eq!(s0.horizon_s, 0.0);
        assert!(s0.kv_capacity_bytes > 0.0);

        stack.push(reqs[0].clone());
        let s1 = stack.snapshot(0);
        assert_eq!(s1.queue_depth, 1);
        assert!(s1.horizon_s > 0.0, "horizon ledger folds the estimate");
        assert!(s1.kv_committed_bytes > 0.0, "queued peak is committed");
        assert_eq!(s1.outstanding_steps, 10);

        stack.push(reqs[1].clone());
        let s2 = stack.snapshot(0);
        assert!(s2.horizon_s > s1.horizon_s);
        assert_eq!(s2.outstanding_steps, 16);

        // Serving moves commitments from the queue ledger into the pool
        // without losing them, and the EWMAs start tracking.
        let out = stack.finish();
        assert_eq!(out.telemetry.completed, 2);
    }

    #[test]
    fn chunked_long_prompt_splits_and_accounts_like_unchunked() {
        // seq 256 at chunk 64: four chunks, one logical prefill, then
        // the same decode lifecycle — and the same KV peak — as the
        // whole-prompt path.
        let mut dc = base_config();
        dc.chunk_tokens = 64;
        let chunked = run_one(vec![gen_req(0, 0.0, 256, 5)], &dc);
        let t = &chunked.telemetry;
        assert_eq!(t.completed, 1);
        assert_eq!(t.prefill_chunks, 4, "256 tokens / 64-token budget");
        assert_eq!(t.prefill_batches, 1, "one logical prefill");
        assert_eq!(t.tokens_out, 5);
        assert_eq!(t.decode_steps, 4);
        assert_eq!(t.ttft_us.count(), 1);

        let plain = run_one(vec![gen_req(0, 0.0, 256, 5)], &base_config());
        assert!(
            (t.peak_kv_bytes - plain.telemetry.peak_kv_bytes).abs() < 1e-6,
            "chunked cache growth must land on the same footprint"
        );
        assert!(t.ttft_us.max() > 0 && t.sm_busy_s > 0.0 && t.energy_j > 0.0);
    }

    #[test]
    fn prompt_shorter_or_equal_to_chunk_never_chunks() {
        // Shorter than the budget and exactly the budget both take the
        // whole-batch path: no chunk-lane activity at all.
        for seq in [64usize, 128] {
            let mut dc = base_config();
            dc.chunk_tokens = 128;
            let out = run_one(vec![gen_req(0, 0.0, seq, 6)], &dc);
            let plain = run_one(vec![gen_req(0, 0.0, seq, 6)], &base_config());
            let (a, b) = (&out.telemetry, &plain.telemetry);
            assert_eq!(a.prefill_chunks, 0, "seq {seq} fits one action");
            assert_eq!(a.completed, 1);
            assert_eq!(a.prefill_batches, b.prefill_batches);
            assert_eq!(a.tokens_out, b.tokens_out);
            assert_eq!(a.decode_steps, b.decode_steps);
            assert_eq!(a.ttft_us.max(), b.ttft_us.max(), "identical prefill timing");
            assert_eq!(a.itl_us.max(), b.itl_us.max());
        }
    }

    #[test]
    fn chunking_interleaves_decode_steps_and_bounds_stalls() {
        // A generation is mid-flight when a long prompt arrives. The
        // whole-prompt path stalls it for the full 512-token prefill;
        // the chunk lane alternates chunk / decode step, so its worst
        // inter-token gap shrinks.
        let reqs = || vec![gen_req(0, 0.0, 64, 200), gen_req(1, 0.001, 512, 2)];
        let plain = run_one(reqs(), &base_config());
        let mut dc = base_config();
        dc.chunk_tokens = 64;
        let chunked = run_one(reqs(), &dc);
        assert_eq!(plain.telemetry.completed, 2);
        assert_eq!(chunked.telemetry.completed, 2);
        assert_eq!(chunked.telemetry.tokens_out, plain.telemetry.tokens_out);
        assert_eq!(chunked.telemetry.prefill_chunks, 8, "512 / 64");
        assert!(
            chunked.telemetry.itl_us.max() < plain.telemetry.itl_us.max(),
            "chunked worst stall {} µs must beat whole-prompt {} µs",
            chunked.telemetry.itl_us.max(),
            plain.telemetry.itl_us.max()
        );
    }

    #[test]
    fn fail_surrenders_all_work_and_releases_kv() {
        let cfg = Config::default();
        let dc = base_config();
        let reqs = vec![
            gen_req(0, 0.0, 128, 50),
            gen_req(1, 0.0, 128, 50),
            gen_req(2, 0.5, 64, 5),
        ];
        let table = phases::phase_table_with_chunks(&cfg, &reqs, dc.chunk_tokens, 1);
        let keys = phases::decode_keys(&reqs);
        let engine = DecodeEngine::build(&cfg, &keys);
        let mut stack = DecodeStack::new(&cfg, &dc, &table, &engine);
        for r in &reqs {
            stack.push(r.clone());
        }
        // Both t=0 requests prefill into the running set; id 2 stays
        // un-ingested (arrival 0.5).
        stack.step_until(0.01);
        let surrendered = stack.fail(0.01);
        let ids: Vec<u64> = surrendered.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2, 0, 1], "pending, then queued, then running");
        assert!(
            surrendered.iter().all(|r| r.id == 2 || r.input.is_none()),
            "mid-flight generations surrender without cached input"
        );
        let out = stack.finish();
        let t = &out.telemetry;
        assert_eq!(t.completed, 0);
        assert_eq!(t.completed + t.shed + t.refused_kv, t.submitted);
        assert_eq!(out.kv_reserved_end_bytes, 0.0, "no leaked reservations");
        assert_eq!(out.kv_used_end_bytes, 0.0, "no leaked cache bytes");
    }

    #[test]
    fn kv_refusal_at_ingest_and_pressure_queues() {
        // Budget below one request's peak: refused at the door.
        let mut dc = base_config();
        dc.kv.capacity_bytes = 1024.0 * 1024.0; // 1 MiB ≪ bert-base peak
        let out = run_one(vec![gen_req(0, 0.0, 256, 64)], &dc);
        assert_eq!(out.telemetry.refused_kv, 1);
        assert_eq!(out.telemetry.completed, 0);

        // Budget for ~one concurrent request: the second must wait for
        // the first to release, not run alongside it.
        let mut dc = base_config();
        let dw = crate::model::DecodeWorkload::build(
            ModelId::BertBase,
            ArchVariant::EncoderOnly,
        );
        dc.kv.capacity_bytes = dw.peak_kv_bytes(128, 40) * 1.5;
        let out = run_one(
            vec![gen_req(0, 0.0, 128, 40), gen_req(1, 0.0, 128, 40)],
            &dc,
        );
        let t = &out.telemetry;
        assert_eq!(t.completed, 2);
        assert_eq!(t.peak_running, 1, "KV pressure serializes");
    }

    #[test]
    fn incremental_counters_match_walking_oracle() {
        // Satellite pin: the O(1) outstanding/depth counters must track
        // the walking implementation through every lifecycle edge —
        // pending, ingest, chunking, retirement, age-out shedding.
        let cfg = Config::default();
        let mut dc = base_config();
        dc.chunk_tokens = 64;
        dc.throttle.max_queue_wait_s = 0.002; // force age-out sheds
        let reqs = vec![
            gen_req(0, 0.0, 256, 12),
            gen_req(1, 0.0, 64, 1),
            gen_req(2, 0.0005, 128, 6),
            gen_req(3, 0.001, 64, 4),
            gen_req(4, 0.3, 512, 8),
        ];
        let table = phases::phase_table_with_chunks(&cfg, &reqs, dc.chunk_tokens, 1);
        let keys = phases::decode_keys(&reqs);
        let engine = DecodeEngine::build(&cfg, &keys);
        let mut stack = DecodeStack::new(&cfg, &dc, &table, &engine);
        for r in &reqs {
            stack.step_until(r.arrival_s);
            stack.push(r.clone());
            assert_eq!(stack.outstanding, stack.walk_outstanding());
            assert_eq!(stack.depth, stack.walk_queue_depth());
            // A few decisions past the push, invariant checked live.
            for _ in 0..3 {
                let _ = stack.advance(Some(r.arrival_s + 0.01));
                assert_eq!(stack.outstanding, stack.walk_outstanding());
                assert_eq!(stack.depth, stack.walk_queue_depth());
            }
        }
        stack.run_to_completion();
        assert_eq!(stack.outstanding, 0, "a drained stack owes no steps");
        assert_eq!(stack.depth, 0);
        let out = stack.finish();
        let t = &out.telemetry;
        assert_eq!(t.completed + t.shed + t.refused_kv, t.submitted);
        assert!(t.shed > 0, "the tight wait bound must shed something");
    }

    #[test]
    fn handoff_joins_decodes_and_conserves() {
        // A transferred-KV arrival: no local prefill, no local TTFT —
        // the generation joins at generated = 1 once the cache is
        // resident and decodes to EOS, with the pool released at
        // retirement and the transfer priced into the energy fold.
        let cfg = Config::default();
        let dc = base_config();
        let reqs = vec![gen_req(0, 0.0, 128, 8)];
        let table = phases::phase_table_with_chunks(&cfg, &reqs, 0, 1);
        let keys = phases::decode_keys(&reqs);
        let engine = DecodeEngine::build(&cfg, &keys);
        let dw = engine.workload(ModelId::BertBase, ArchVariant::EncoderOnly);
        let kv_bytes = dw.kv_bytes(128, 1);
        let transfer_s = kv_bytes / crate::fleet::interposer_bw_bps();
        let mut stack = DecodeStack::new(&cfg, &dc, &table, &engine);
        stack.push_handoff(KvHandoff {
            id: 0,
            model: ModelId::BertBase,
            variant: ArchVariant::EncoderOnly,
            prompt: 128,
            arrival_s: 0.0,
            first_token_s: 0.004,
            ready_s: 0.004 + transfer_s,
            kv_bytes,
            transfer_s,
            out_tokens: 8,
        });
        assert_eq!(stack.walk_queue_depth(), 1);
        assert_eq!(stack.walk_outstanding(), 7, "first token already emitted");
        assert_eq!(stack.outstanding, stack.walk_outstanding());
        let out = stack.finish();
        let t = &out.telemetry;
        assert_eq!(t.submitted, 1);
        assert_eq!(t.completed, 1);
        assert_eq!(t.tokens_out, 7, "the first token was emitted remotely");
        assert_eq!(t.decode_steps, 7);
        assert_eq!(t.ttft_us.count(), 0, "TTFT belongs to the prefill stack");
        assert_eq!(t.itl_us.count(), 7);
        assert_eq!(t.prefill_batches, 0);
        assert_eq!(t.tpot_us.count(), 1, "TPOT spans the true first token");
        assert_eq!(out.kv_reserved_end_bytes, 0.0, "no leaked reservations");
        assert_eq!(out.kv_used_end_bytes, 0.0, "no leaked cache bytes");
        assert!(t.energy_j > 0.0, "decode + transfer energy folds");
        assert!(t.makespan_s > 0.004 + transfer_s);
    }
}
