//! Continuous-batching decode scheduler: one stack's request-lifecycle
//! loop on a step-level simulated clock.
//!
//! Lifecycle (DESIGN.md §Decode): `Waiting → Prefilling → Decoding →
//! Retired`, with two refusal edges — `refused_kv` at ingest (the peak
//! cache footprint can never fit the stack budget) and `shed` when a
//! waiting request ages past the queue-wait bound (including thermal
//! deferrals that never clear).
//!
//! Scheduling policy: prefill-prioritized continuous batching. Whenever
//! the running batch has room and the thermal controller admits, the
//! head-of-queue run of compatible requests is prefilled as one batch
//! through [`Engine::serve_batch`] (the §4.2 two-tier pipeline, emitting
//! each request's first token); otherwise the whole running set advances
//! one decode step, every request appends one token to its KV cache, and
//! EOS retirements release their reservations. Tier busy time is
//! accounted through the same [`ServeState`]/[`Engine::serve_batch`]
//! horizons the serve path uses; operations issue in decision order
//! (decode's token-to-token dependency serializes them), while the B
//! requests of a prefill batch still pipeline across the two tiers
//! inside `serve_batch`.
//!
//! Chunked prefill (DESIGN.md §Decode): with `chunk_tokens > 0` every
//! prefill action is bounded by the token budget — whole-prompt batches
//! stop accepting members once their summed prompt tokens reach it, and
//! a single prompt longer than the budget prefills alone, chunk by
//! chunk. While generations are running, *every* prefill action (chunk
//! or whole batch) strictly alternates with decode steps, so neither a
//! long prompt nor a queue of short ones can stack stalls. Each chunk
//! is priced through the same [`Engine::serve_batch`] path (at the
//! chunk's length) plus the [`DecodeEngine::chunk_attn_cost`] surcharge
//! for attending over the already-cached prompt prefix, and is gated
//! per-chunk through
//! [`AdmissionController::admit_with_background`]. The worst-case gap
//! between the running set's tokens — the ITL spike the serving
//! literature attributes to head-of-line prefills — is therefore
//! bounded by one budget-sized prefill action plus one decode step.
//! `chunk_tokens = 0` disables the lane and keeps the original
//! whole-prompt path bit for bit (every chunking branch sits behind
//! that gate).
//!
//! Determinism: the loop reads only simulated quantities — arrivals and
//! sampled output lengths come pre-drawn from the seeded generator, the
//! thermal controller is deterministic, and every fold is in a fixed
//! order. A stack's outcome is a pure function of its shard.

use std::collections::HashMap;
use std::collections::VecDeque;

use crate::config::Config;
use crate::coordinator::{Batch, Engine, Request, ServeState};
use crate::decode::engine::{DecodeEngine, StepGroup};
use crate::decode::kv::{KvCacheConfig, KvPool};
use crate::decode::telemetry::DecodeTelemetry;
use crate::model::{ArchVariant, ModelId};
use crate::power;
use crate::traffic::admission::{AdmissionController, BatchCost, ThrottleConfig};
use crate::traffic::generator::{ArrivalPattern, RequestMix};
use crate::traffic::loadtest::{PhaseInfo, PhaseKey};
use crate::traffic::router::RoutePolicy;

/// Full parameterization of one decode run (`hetrax decodetest`).
#[derive(Debug, Clone)]
pub struct DecodeConfig {
    pub pattern: ArrivalPattern,
    /// Must carry an output-length distribution for generation traffic;
    /// requests with `out_tokens == 0` are clamped to one token.
    pub mix: RequestMix,
    pub duration_s: f64,
    pub stacks: usize,
    pub policy: RoutePolicy,
    pub seed: u64,
    pub kv: KvCacheConfig,
    /// Continuous-batch capacity: how many generations decode together.
    /// 1 = one-request-at-a-time serving (the regression baseline).
    pub max_running: usize,
    /// Cap on requests prefilled together in one batch.
    pub max_prefill_batch: usize,
    /// Chunked-prefill token budget: the most prompt tokens one prefill
    /// action may process. 0 disables chunking (whole prompts prefill
    /// in one batch — the pre-chunking behaviour, bit for bit). Prompts
    /// longer than the budget prefill chunk by chunk, interleaved with
    /// decode steps, bounding the worst-case inter-token stall of the
    /// running generations.
    pub chunk_tokens: usize,
    /// Thermal admission knobs (ceiling, control window, queue-wait
    /// bound) — shared with the loadtest controller.
    pub throttle: ThrottleConfig,
    /// Worker threads for the stack fan-out (0 = auto, 1 = serial);
    /// results are identical at any value.
    pub threads: usize,
}

impl DecodeConfig {
    pub fn new(pattern: ArrivalPattern, mix: RequestMix) -> DecodeConfig {
        DecodeConfig {
            pattern,
            mix,
            duration_s: 1.0,
            stacks: 1,
            policy: RoutePolicy::JoinShortestQueue,
            seed: 0xC0DE,
            kv: KvCacheConfig::default(),
            max_running: 8,
            max_prefill_batch: 4,
            chunk_tokens: 0,
            throttle: ThrottleConfig::default(),
            threads: 0,
        }
    }
}

/// One stack's results.
#[derive(Debug, Clone)]
pub struct DecodeStackOutcome {
    pub telemetry: DecodeTelemetry,
    pub peak_c: f64,
    pub reram_peak_c: f64,
    pub throttle_events: u64,
    pub windows: u64,
}

/// A request mid-generation.
#[derive(Debug, Clone)]
struct ActiveGen {
    model: ModelId,
    variant: ArchVariant,
    prompt: usize,
    out_tokens: usize,
    arrival_s: f64,
    /// Output tokens emitted so far (the prefill emits the first).
    generated: usize,
    first_token_s: f64,
    last_token_s: f64,
    /// Peak-footprint reservation held in the KV pool.
    peak_kv: f64,
    /// Bytes actually written so far.
    used_kv: f64,
}

/// A prompt mid-chunking: its first chunks are cached, the rest still
/// to prefill. At most one exists per stack (the chunk lane serves the
/// head of the queue); the peak reservation is held from the first
/// admitted chunk, so the prompt can never be evicted between chunks.
#[derive(Debug, Clone)]
struct PartialPrefill {
    req: Request,
    /// Prompt tokens already prefilled and cached.
    done: usize,
    peak_kv: f64,
    used_kv: f64,
}

fn us(seconds: f64) -> u64 {
    (seconds.max(0.0) * 1e6).round() as u64
}

/// Group the running set per (model, variant) in first-seen order.
fn step_groups(engine: &DecodeEngine, running: &[ActiveGen]) -> Vec<StepGroup> {
    let mut groups: Vec<StepGroup> = Vec::new();
    for a in running {
        let dw = engine.workload(a.model, a.variant);
        let sctx = dw.self_context(a.prompt, a.generated);
        let cctx = if dw.cross { a.prompt } else { 0 };
        match groups
            .iter_mut()
            .find(|g| g.model == a.model && g.variant == a.variant)
        {
            Some(g) => {
                g.b += 1;
                g.sum_self_ctx += sctx;
                g.sum_cross_ctx += cctx;
            }
            None => groups.push(StepGroup {
                model: a.model,
                variant: a.variant,
                b: 1,
                sum_self_ctx: sctx,
                sum_cross_ctx: cctx,
            }),
        }
    }
    groups
}

/// Steady-state busy seconds one control window of the current decode
/// batch contributes — the un-throttleable background the admission
/// controller prices prefills against.
fn decode_background(
    engine: &DecodeEngine,
    running: &[ActiveGen],
    interval_s: f64,
) -> BatchCost {
    if running.is_empty() {
        return BatchCost::zero();
    }
    let groups = step_groups(engine, running);
    let sc = engine.step_cost(&groups);
    let total = (sc.mha_s + sc.ff_s).max(1e-12);
    let frac = groups
        .iter()
        .map(|g| engine.active_frac(g.model, g.variant))
        .fold(0.0f64, f64::max);
    BatchCost {
        sm_s: interval_s * sc.mha_s / total,
        ff_s: interval_s * sc.ff_s / total,
        active_frac: frac,
    }
}

fn retire(tel: &mut DecodeTelemetry, kv: &mut KvPool, a: ActiveGen) {
    tel.completed += 1;
    tel.e2e_us.record(us(a.last_token_s - a.arrival_s));
    if a.out_tokens > 1 {
        let tpot = (a.last_token_s - a.first_token_s) / (a.out_tokens - 1) as f64;
        tel.tpot_us.record(us(tpot));
    }
    tel.makespan_s = tel.makespan_s.max(a.last_token_s);
    kv.release(a.peak_kv, a.used_kv);
}

/// Run one stack's decode loop over its (arrival-sorted) shard.
pub(crate) fn serve_stack(
    cfg: &Config,
    dc: &DecodeConfig,
    phases: &HashMap<PhaseKey, PhaseInfo>,
    engine: &DecodeEngine,
    reqs: &[Request],
) -> DecodeStackOutcome {
    let mut tel = DecodeTelemetry::new();
    tel.submitted = reqs.len() as u64;
    let mut ctl = AdmissionController::new(cfg, dc.throttle, dc.max_prefill_batch);
    if reqs.is_empty() {
        return DecodeStackOutcome {
            telemetry: tel,
            peak_c: 0.0,
            reram_peak_c: 0.0,
            throttle_events: 0,
            windows: 0,
        };
    }

    let serve_engine = Engine::new(cfg);
    let mut state = ServeState::new();
    let mut kv = KvPool::new(dc.kv);
    let interval = dc.throttle.interval_s.max(1e-6);
    let wait = dc.throttle.max_queue_wait_s;
    let max_running = dc.max_running.max(1);

    // Backstop against config pathologies: every iteration either emits
    // tokens, serves a prefill chunk, launches a prefill, or advances
    // the clock by ≥ one control window, so this cap is far above any
    // legitimate run.
    let total_tokens: u64 = reqs.iter().map(|r| r.out_tokens.max(1) as u64).sum();
    let total_chunks: u64 = if dc.chunk_tokens > 0 {
        reqs.iter()
            .map(|r| ((r.seq + dc.chunk_tokens - 1) / dc.chunk_tokens) as u64)
            .sum()
    } else {
        0
    };
    let max_ops = 4 * (total_tokens
        + total_chunks
        + reqs.len() as u64
        + ((dc.duration_s + wait) / interval).ceil() as u64)
        + 1024;

    let mut waiting: VecDeque<Request> = VecDeque::new();
    let mut running: Vec<ActiveGen> = Vec::new();
    // The chunk lane (chunk_tokens > 0 only): at most one prompt
    // mid-chunking, and an alternation flag forcing one decode step
    // between consecutive chunks while generations are running.
    let mut partial: Option<PartialPrefill> = None;
    let mut chunk_turn = true;
    let mut next = 0usize;
    let mut t = 0.0f64;
    // Thermal deferral gate: no prefill attempts before this time.
    let mut admit_block_until = 0.0f64;
    // Work already admitted in the current control window (priced as
    // background so sustained launches accumulate heat).
    let mut window_cost = BatchCost::zero();
    let mut window_end = interval;
    // Decode-phase accumulators for the end-of-run energy model.
    let mut dec_sm_flops = 0.0f64;
    let mut dec_ff_ops = 0.0f64;
    let mut dec_l2_bytes = 0.0f64;
    let mut dec_kv_bytes = 0.0f64;
    let mut dec_mha_busy = 0.0f64;
    let mut dec_ff_busy = 0.0f64;
    // Simulated control windows elapsed (what `control_windows` reports;
    // the controller's own counter counts admission *decisions*).
    let mut sim_windows = 0u64;
    let mut ops = 0u64;

    loop {
        // Window bookkeeping on the simulated clock (O(1) even across
        // long idle jumps; the while is a float-rounding backstop).
        if t >= window_end {
            // Close the window's thermal book first: decode-heavy
            // stretches make no admission calls, so the committed
            // running batch plus this window's admitted work is
            // recorded here.
            let mut closing = decode_background(engine, &running, interval);
            closing.add(&window_cost);
            ctl.observe(&closing);
            let mut k = ((t - window_end) / interval).floor() as u64 + 1;
            window_end += k as f64 * interval;
            while t >= window_end {
                window_end += interval;
                k += 1;
            }
            sim_windows += k;
            window_cost = BatchCost::zero();
        }

        // 1. Ingest arrivals due by now; refuse outright what can never
        //    fit the stack's cache budget.
        while next < reqs.len() && reqs[next].arrival_s <= t {
            let r = &reqs[next];
            let dw = engine.workload(r.model, r.variant);
            if dw.peak_kv_bytes(r.seq, r.out_tokens.max(1)) > kv.capacity_bytes() {
                tel.refused_kv += 1;
            } else {
                waiting.push_back(r.clone());
            }
            next += 1;
        }

        // 2. Age out waiting requests past the queue bound.
        let before = waiting.len();
        waiting.retain(|r| t - r.arrival_s <= wait);
        tel.shed += (before - waiting.len()) as u64;

        // 3. Advance prefill work. The chunk lane (chunking only) takes
        //    precedence: it continues the in-flight partial prompt, or
        //    promotes the head of the queue when its prompt exceeds the
        //    budget. Otherwise one whole prefill batch may launch —
        //    token-budget-capped when chunking is on, exactly the
        //    pre-chunking path when it is off.
        let mut launched = false;
        let chunking = dc.chunk_tokens > 0;
        if chunking && t >= admit_block_until && (running.is_empty() || chunk_turn) {
            // Pick the chunk job: the partial already holding its
            // reservation, else the un-popped queue head (it stays
            // ageable in `waiting` until its first chunk is admitted).
            let job: Option<(Request, usize, f64, f64)> = match partial.take() {
                Some(p) => Some((p.req, p.done, p.peak_kv, p.used_kv)),
                None if running.len() < max_running
                    && !waiting.is_empty()
                    && waiting[0].seq > dc.chunk_tokens =>
                {
                    let r = &waiting[0];
                    let peak = engine
                        .workload(r.model, r.variant)
                        .peak_kv_bytes(r.seq, r.out_tokens.max(1));
                    if kv.would_fit(peak) {
                        Some((r.clone(), 0, peak, 0.0))
                    } else {
                        None
                    }
                }
                None => None,
            };
            if let Some((req, mut done, peak_kv, mut used_kv)) = job {
                let c = dc.chunk_tokens.min(req.seq - done);
                let mut chunk_req = req.clone();
                chunk_req.seq = c;
                let batch = Batch { requests: vec![chunk_req], ready_s: t };
                let info = phases[&(req.model, req.variant, c)];
                let surcharge =
                    engine.chunk_attn_cost(req.model, req.variant, c, done);
                let cost = BatchCost {
                    sm_s: info.mha_s + surcharge.mha_s,
                    ff_s: info.ff_s,
                    active_frac: info.active_frac,
                };
                let mut background = decode_background(engine, &running, interval);
                background.add(&window_cost);
                let (admitted, _deferred) =
                    ctl.admit_with_background(t, vec![batch], &[cost], background);
                if let Some(batch) = admitted.into_iter().next() {
                    if done == 0 {
                        // First chunk: the prompt commits — leave the
                        // queue, hold the peak reservation to EOS.
                        waiting.pop_front();
                        let ok = kv.try_reserve(peak_kv);
                        debug_assert!(ok, "reservation was pre-checked");
                    }
                    let out = serve_engine
                        .serve_batch(&mut state, &batch)
                        .expect("chunk batch is non-empty");
                    // The prior-prefix attention runs on the SM tiers
                    // right after the chunk's own phases.
                    let end = out.finish_s + surcharge.mha_s;
                    state.sm_free = state.sm_free.max(end);
                    t = end;
                    window_cost.add(&cost);
                    tel.prefill_chunks += 1;
                    tel.sm_busy_s += out.sm_busy_s + surcharge.mha_s;
                    tel.reram_busy_s += out.reram_busy_s;
                    tel.energy_j += out.energy_j;
                    dec_mha_busy += surcharge.mha_s;
                    dec_sm_flops += surcharge.sm_flops;
                    dec_kv_bytes += surcharge.kv_read_bytes;
                    let dw = engine.workload(req.model, req.variant);
                    let grow = dw.kv_bytes(done + c, 0) - dw.kv_bytes(done, 0);
                    kv.grow(grow);
                    used_kv += grow;
                    done += c;
                    if done >= req.seq {
                        // Prompt complete: the prefill emits the first
                        // token, exactly like the whole-batch path.
                        let first = dw.kv_bytes(req.seq, 1) - dw.kv_bytes(req.seq, 0);
                        kv.grow(first);
                        used_kv += first;
                        let out_tokens = req.out_tokens.max(1);
                        tel.prefill_batches += 1;
                        tel.tokens_out += 1;
                        tel.ttft_us.record(us(t - req.arrival_s));
                        let a = ActiveGen {
                            model: req.model,
                            variant: req.variant,
                            prompt: req.seq,
                            out_tokens,
                            arrival_s: req.arrival_s,
                            generated: 1,
                            first_token_s: t,
                            last_token_s: t,
                            peak_kv,
                            used_kv,
                        };
                        if a.generated >= a.out_tokens {
                            retire(&mut tel, &mut kv, a);
                        } else {
                            running.push(a);
                        }
                        tel.peak_running = tel.peak_running.max(running.len() as u64);
                    } else {
                        partial = Some(PartialPrefill { req, done, peak_kv, used_kv });
                    }
                    tel.peak_kv_bytes = tel.peak_kv_bytes.max(kv.used_bytes());
                    chunk_turn = false;
                    launched = true;
                } else {
                    // Thermally deferred: hold the chunk lane for the
                    // rest of this control window; an in-flight partial
                    // keeps its reservation, an unpromoted head stays
                    // queued (and ageable).
                    admit_block_until = window_end;
                    if done > 0 {
                        partial = Some(PartialPrefill { req, done, peak_kv, used_kv });
                    }
                }
            }
        }

        // Whole-batch prefill launch (continuous-batching join). Blocked
        // while a partial prompt owns the chunk lane; with chunking on,
        // a long head prompt is chunk-lane work, never a whole batch,
        // and whole batches obey the same chunk/decode alternation —
        // otherwise a queue of short prompts would launch budget-sized
        // batches back to back and stack stalls the budget exists to
        // bound.
        let room = max_running.saturating_sub(running.len());
        if !launched
            && partial.is_none()
            && room > 0
            && !waiting.is_empty()
            && t >= admit_block_until
            && (!chunking || waiting[0].seq <= dc.chunk_tokens)
            && (!chunking || running.is_empty() || chunk_turn)
        {
            let head = (waiting[0].model, waiting[0].variant);
            let cap = room.min(dc.max_prefill_batch).min(ctl.batch_cap).max(1);
            let mut cand = 0usize;
            let mut kv_need = 0.0f64;
            let mut tok_need = 0usize;
            for r in waiting.iter() {
                if cand >= cap || (r.model, r.variant) != head {
                    break;
                }
                if chunking && cand > 0 && tok_need + r.seq > dc.chunk_tokens {
                    break;
                }
                let peak = engine
                    .workload(r.model, r.variant)
                    .peak_kv_bytes(r.seq, r.out_tokens.max(1));
                if !kv.would_fit(kv_need + peak) {
                    break;
                }
                kv_need += peak;
                tok_need += r.seq;
                cand += 1;
            }
            if cand > 0 {
                let batch = Batch {
                    requests: waiting.iter().take(cand).cloned().collect(),
                    ready_s: t,
                };
                let info = phases[&(head.0, head.1, batch.seq())];
                let n = cand as f64;
                let cost = BatchCost {
                    sm_s: info.mha_s * n,
                    ff_s: info.ff_s * n,
                    active_frac: info.active_frac,
                };
                let mut background = decode_background(engine, &running, interval);
                background.add(&window_cost);
                let (admitted, _deferred) =
                    ctl.admit_with_background(t, vec![batch], &[cost], background);
                if let Some(batch) = admitted.into_iter().next() {
                    let out = serve_engine
                        .serve_batch(&mut state, &batch)
                        .expect("prefill batch is non-empty");
                    window_cost.add(&cost);
                    tel.prefill_batches += 1;
                    tel.sm_busy_s += out.sm_busy_s;
                    tel.reram_busy_s += out.reram_busy_s;
                    tel.energy_j += out.energy_j;
                    t = out.finish_s;
                    for r in waiting.drain(..cand) {
                        let dw = engine.workload(r.model, r.variant);
                        let out_tokens = r.out_tokens.max(1);
                        let peak = dw.peak_kv_bytes(r.seq, out_tokens);
                        let ok = kv.try_reserve(peak);
                        debug_assert!(ok, "reservation was pre-checked");
                        let used = dw.kv_bytes(r.seq, 1);
                        kv.grow(used);
                        tel.tokens_out += 1;
                        tel.ttft_us.record(us(t - r.arrival_s));
                        let a = ActiveGen {
                            model: r.model,
                            variant: r.variant,
                            prompt: r.seq,
                            out_tokens,
                            arrival_s: r.arrival_s,
                            generated: 1,
                            first_token_s: t,
                            last_token_s: t,
                            peak_kv: peak,
                            used_kv: used,
                        };
                        if a.generated >= a.out_tokens {
                            retire(&mut tel, &mut kv, a);
                        } else {
                            running.push(a);
                        }
                    }
                    tel.peak_running = tel.peak_running.max(running.len() as u64);
                    tel.peak_kv_bytes = tel.peak_kv_bytes.max(kv.used_bytes());
                    if chunking {
                        chunk_turn = false;
                    }
                    launched = true;
                } else {
                    // Thermally deferred: hold admissions for the rest
                    // of this control window.
                    admit_block_until = window_end;
                }
            }
        }

        if !launched && !running.is_empty() {
            // 4. One decode step over the whole running set.
            let groups = step_groups(engine, &running);
            let sc = engine.step_cost(&groups);
            let start = t;
            let end = start + sc.wall_s;
            state.sm_free = state.sm_free.max(start + sc.mha_s);
            state.reram_free = state.reram_free.max(end);
            t = end;
            tel.decode_steps += 1;
            tel.sm_busy_s += sc.mha_s;
            tel.reram_busy_s += sc.ff_s;
            dec_mha_busy += sc.mha_s;
            dec_ff_busy += sc.ff_s;
            dec_sm_flops += sc.sm_flops;
            dec_ff_ops += sc.ff_ops;
            dec_l2_bytes += sc.l2_bytes;
            dec_kv_bytes += sc.kv_read_bytes;

            let mut i = 0;
            while i < running.len() {
                let a = &mut running[i];
                a.generated += 1;
                tel.itl_us.record(us(end - a.last_token_s));
                a.last_token_s = end;
                let grow = engine.workload(a.model, a.variant).kv_bytes_per_token();
                kv.grow(grow);
                a.used_kv += grow;
                tel.tokens_out += 1;
                if a.generated >= a.out_tokens {
                    let done = running.remove(i);
                    retire(&mut tel, &mut kv, done);
                } else {
                    i += 1;
                }
            }
            tel.kv_used_kib.record((kv.used_bytes() / 1024.0).round() as u64);
            tel.peak_kv_bytes = tel.peak_kv_bytes.max(kv.used_bytes());
            chunk_turn = true;
            launched = true;
        }

        if !launched {
            // 5. Idle: advance to the next meaningful instant.
            let pending = partial.is_some() || !waiting.is_empty();
            if pending && t < admit_block_until {
                t = admit_block_until;
            } else if !pending && next < reqs.len() {
                t = reqs[next].arrival_s;
            } else if !pending {
                break;
            } else {
                // Defensive: pending prefill work unlaunchable with an
                // empty pool cannot happen (refusal is checked at
                // ingest, partial reservations are pre-checked), but
                // never spin — shed it and move on.
                if let Some(p) = partial.take() {
                    kv.release(p.peak_kv, p.used_kv);
                } else {
                    waiting.pop_front();
                }
                tel.shed += 1;
            }
        }

        ops += 1;
        if ops >= max_ops {
            // Conservation even on abort: un-ingested arrivals count as
            // shed too, so completed + shed + refused_kv == submitted.
            tel.shed += waiting.len() as u64
                + running.len() as u64
                + partial.is_some() as u64
                + (reqs.len() - next) as u64;
            for a in running.drain(..) {
                kv.release(a.peak_kv, a.used_kv);
            }
            if let Some(p) = partial.take() {
                kv.release(p.peak_kv, p.used_kv);
            }
            waiting.clear();
            break;
        }
    }

    // Decode-phase energy (prefill energy came through serve_batch):
    // SM + ReRAM dynamic/static over their busy windows, L2 traffic,
    // and the DRAM-side KV stream.
    tel.energy_j += power::sm_energy_j(cfg, dec_sm_flops, dec_mha_busy, 1.0)
        + power::reram_energy_j(cfg, dec_ff_ops, dec_ff_busy)
        + power::mc_energy_j(cfg, dec_l2_bytes, dec_mha_busy)
        + power::dram_energy_j(dec_kv_bytes);

    DecodeStackOutcome {
        telemetry: tel,
        peak_c: ctl.peak_c,
        reram_peak_c: ctl.reram_peak_c,
        throttle_events: ctl.events.len() as u64,
        windows: sim_windows,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traffic::loadtest;

    fn run_one(reqs: Vec<Request>, dc: &DecodeConfig) -> DecodeStackOutcome {
        let cfg = Config::default();
        let phases = loadtest::phase_table_with_chunks(&cfg, &reqs, dc.chunk_tokens, 1);
        let mut keys: Vec<(ModelId, ArchVariant)> = Vec::new();
        for r in &reqs {
            if !keys.contains(&(r.model, r.variant)) {
                keys.push((r.model, r.variant));
            }
        }
        let engine = DecodeEngine::build(&cfg, &keys);
        serve_stack(&cfg, dc, &phases, &engine, &reqs)
    }

    fn gen_req(id: u64, arrival: f64, prompt: usize, out: usize) -> Request {
        let mut r = Request::synthetic(id, ModelId::BertBase, prompt, arrival);
        r.out_tokens = out;
        r
    }

    fn base_config() -> DecodeConfig {
        DecodeConfig::new(
            ArrivalPattern::Poisson { rps: 0.0 },
            RequestMix::single(ModelId::BertBase),
        )
    }

    #[test]
    fn single_request_lifecycle() {
        let dc = base_config();
        let out = run_one(vec![gen_req(0, 0.0, 128, 5)], &dc);
        let t = &out.telemetry;
        assert_eq!(t.submitted, 1);
        assert_eq!(t.completed, 1);
        assert_eq!(t.shed + t.refused_kv, 0);
        assert_eq!(t.tokens_out, 5);
        assert_eq!(t.prefill_batches, 1);
        assert_eq!(t.decode_steps, 4, "first token from prefill, 4 stepped");
        assert_eq!(t.itl_us.count(), 4);
        assert_eq!(t.ttft_us.count(), 1);
        assert_eq!(t.tpot_us.count(), 1);
        assert!(t.ttft_us.max() > 0, "prefill takes simulated time");
        assert!(t.makespan_s > 0.0);
        assert!(t.peak_kv_bytes > 0.0);
        assert!(t.sm_busy_s > 0.0 && t.reram_busy_s > 0.0);
        assert!(t.energy_j > 0.0);
    }

    #[test]
    fn one_token_request_retires_at_prefill() {
        let dc = base_config();
        let out = run_one(vec![gen_req(0, 0.0, 64, 1)], &dc);
        let t = &out.telemetry;
        assert_eq!(t.completed, 1);
        assert_eq!(t.tokens_out, 1);
        assert_eq!(t.decode_steps, 0);
        assert_eq!(t.itl_us.count(), 0);
        assert_eq!(t.tpot_us.count(), 0, "TPOT undefined for 1-token outputs");
        assert_eq!(t.e2e_us.count(), 1);
    }

    #[test]
    fn later_arrival_joins_running_batch() {
        // Second request arrives mid-generation of the first: it must
        // join (peak_running = 2) rather than wait for completion.
        let dc = base_config();
        let out = run_one(
            vec![gen_req(0, 0.0, 128, 200), gen_req(1, 0.002, 128, 200)],
            &dc,
        );
        let t = &out.telemetry;
        assert_eq!(t.completed, 2);
        assert_eq!(t.peak_running, 2, "continuous batching must join");
        assert_eq!(t.tokens_out, 400);
    }

    #[test]
    fn serial_mode_never_overlaps_requests() {
        let mut dc = base_config();
        dc.max_running = 1;
        let out = run_one(
            vec![gen_req(0, 0.0, 128, 50), gen_req(1, 0.0, 128, 50)],
            &dc,
        );
        let t = &out.telemetry;
        assert_eq!(t.completed, 2);
        assert_eq!(t.peak_running, 1);
        assert_eq!(t.prefill_batches, 2, "one at a time");
    }

    #[test]
    fn chunked_long_prompt_splits_and_accounts_like_unchunked() {
        // seq 256 at chunk 64: four chunks, one logical prefill, then
        // the same decode lifecycle — and the same KV peak — as the
        // whole-prompt path.
        let mut dc = base_config();
        dc.chunk_tokens = 64;
        let chunked = run_one(vec![gen_req(0, 0.0, 256, 5)], &dc);
        let t = &chunked.telemetry;
        assert_eq!(t.completed, 1);
        assert_eq!(t.prefill_chunks, 4, "256 tokens / 64-token budget");
        assert_eq!(t.prefill_batches, 1, "one logical prefill");
        assert_eq!(t.tokens_out, 5);
        assert_eq!(t.decode_steps, 4);
        assert_eq!(t.ttft_us.count(), 1);

        let plain = run_one(vec![gen_req(0, 0.0, 256, 5)], &base_config());
        assert!(
            (t.peak_kv_bytes - plain.telemetry.peak_kv_bytes).abs() < 1e-6,
            "chunked cache growth must land on the same footprint"
        );
        assert!(t.ttft_us.max() > 0 && t.sm_busy_s > 0.0 && t.energy_j > 0.0);
    }

    #[test]
    fn prompt_shorter_or_equal_to_chunk_never_chunks() {
        // Shorter than the budget and exactly the budget both take the
        // whole-batch path: no chunk-lane activity at all.
        for seq in [64usize, 128] {
            let mut dc = base_config();
            dc.chunk_tokens = 128;
            let out = run_one(vec![gen_req(0, 0.0, seq, 6)], &dc);
            let plain = run_one(vec![gen_req(0, 0.0, seq, 6)], &base_config());
            let (a, b) = (&out.telemetry, &plain.telemetry);
            assert_eq!(a.prefill_chunks, 0, "seq {seq} fits one action");
            assert_eq!(a.completed, 1);
            assert_eq!(a.prefill_batches, b.prefill_batches);
            assert_eq!(a.tokens_out, b.tokens_out);
            assert_eq!(a.decode_steps, b.decode_steps);
            assert_eq!(a.ttft_us.max(), b.ttft_us.max(), "identical prefill timing");
            assert_eq!(a.itl_us.max(), b.itl_us.max());
        }
    }

    #[test]
    fn chunking_interleaves_decode_steps_and_bounds_stalls() {
        // A generation is mid-flight when a long prompt arrives. The
        // whole-prompt path stalls it for the full 512-token prefill;
        // the chunk lane alternates chunk / decode step, so its worst
        // inter-token gap shrinks.
        let reqs = || vec![gen_req(0, 0.0, 64, 200), gen_req(1, 0.001, 512, 2)];
        let plain = run_one(reqs(), &base_config());
        let mut dc = base_config();
        dc.chunk_tokens = 64;
        let chunked = run_one(reqs(), &dc);
        assert_eq!(plain.telemetry.completed, 2);
        assert_eq!(chunked.telemetry.completed, 2);
        assert_eq!(chunked.telemetry.tokens_out, plain.telemetry.tokens_out);
        assert_eq!(chunked.telemetry.prefill_chunks, 8, "512 / 64");
        assert!(
            chunked.telemetry.itl_us.max() < plain.telemetry.itl_us.max(),
            "chunked worst stall {} µs must beat whole-prompt {} µs",
            chunked.telemetry.itl_us.max(),
            plain.telemetry.itl_us.max()
        );
    }

    #[test]
    fn kv_refusal_at_ingest_and_pressure_queues() {
        // Budget below one request's peak: refused at the door.
        let mut dc = base_config();
        dc.kv.capacity_bytes = 1024.0 * 1024.0; // 1 MiB ≪ bert-base peak
        let out = run_one(vec![gen_req(0, 0.0, 256, 64)], &dc);
        assert_eq!(out.telemetry.refused_kv, 1);
        assert_eq!(out.telemetry.completed, 0);

        // Budget for ~one concurrent request: the second must wait for
        // the first to release, not run alongside it.
        let mut dc = base_config();
        let dw = crate::model::DecodeWorkload::build(
            ModelId::BertBase,
            ArchVariant::EncoderOnly,
        );
        dc.kv.capacity_bytes = dw.peak_kv_bytes(128, 40) * 1.5;
        let out = run_one(
            vec![gen_req(0, 0.0, 128, 40), gen_req(1, 0.0, 128, 40)],
            &dc,
        );
        let t = &out.telemetry;
        assert_eq!(t.completed, 2);
        assert_eq!(t.peak_running, 1, "KV pressure serializes");
    }
}
