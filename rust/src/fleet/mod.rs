//! Heterogeneous fleet serving: first-class stack architectures and
//! prefill/decode disaggregation with KV transfer over the interconnect.
//!
//! HeTraX is one point in a family of heterogeneous transformer
//! accelerators. This module makes the *stack architecture* a per-stack
//! config instead of a global constant: a [`StackArch`] descriptor bundles
//! the tier layout (SM/MC counts and grid), thermal ceiling, KV budget
//! split, and a relative compute scale, with three presets:
//!
//! * [`StackArchId::Hetrax3d`] — today's numbers, the exact default. Its
//!   descriptor applies **no** overrides: `config()`, `kv_config()` and
//!   `throttle()` are bitwise no-ops, which is what makes a homogeneous
//!   fleet byte-identical to the pre-fleet cluster path.
//! * [`StackArchId::Chiplet2p5d`] — the 2.5D chiplet sibling
//!   (arxiv 2312.11750): a larger SM tier (40 SM / 8 MC on a 4×4 grid),
//!   more KV capacity routed over the interposer, but a lower thermal
//!   ceiling because the interposer spreads less heat than a full 3D
//!   stack's TSV field.
//! * [`StackArchId::AtleusEdge`] — the Atleus edge stacks
//!   (arxiv 2501.09588): small tiers (9 SM / 3 MC on a 2×2 grid), a tight
//!   ceiling, half the KV budget, and cheap idle (lower per-tile power).
//!
//! Snapshots carry the arch id and a `compute_scale` so the `jsq` / `kv` /
//! `latency` policies normalize queue pressure by capacity instead of
//! assuming identical stacks (see `traffic::router`). For `hetrax3d` the
//! scale is exactly 1.0 and the normalizing division is bitwise exact.
//!
//! # Disaggregated serving
//!
//! [`run_disaggregated`] splits a fleet into prefill-specialized and
//! decode-specialized stacks. Arrivals route (policy-chosen) to a prefill
//! stack with their output budget clamped to a single token; when the
//! prefill completes, its KV cache is handed to a decode stack chosen
//! KV-aware *at hand-off time* against fresh snapshots. The hand-off is
//! charged a modeled transfer cost — `kv_bytes / interposer_bw_bps()`,
//! using the same NoC flit clock the energy model uses — as virtual-time
//! delay before the first decode step, and the wire time is priced into
//! the decode stack's thermal background (see
//! `DecodeStack::push_handoff`). Transfer energy is folded into the
//! decode stack's energy total via [`transfer_energy_j`].
//!
//! Event ordering per arrival time `t` (and at stream end) is fixed and
//! serial, which makes the whole driver deterministic across runs and
//! thread counts:
//!
//! 1. if a crash is scheduled at `t_c <= t`, crash that stack (step all
//!    stacks to `t_c`, deliver pre-crash completions, then surrender the
//!    victim's queue and re-route survivors to the remaining prefill
//!    stacks);
//! 2. step every stack to `t` in index order;
//! 3. drain prefill completion logs in index order;
//! 4. deliver hand-offs sorted by `(finish_s, id)`, each routed against a
//!    fresh snapshot of the decode stacks;
//! 5. route the arrival itself to a prefill stack.

use std::collections::HashMap;

use crate::cluster::{ClusterStack, HealthState, StackSnapshot};
use crate::config::{specs, Config};
use crate::decode::decodetest::{self, DecodeReport};
use crate::decode::engine::DecodeEngine;
use crate::decode::kv::KvCacheConfig;
use crate::decode::scheduler::{
    Completion, DecodeConfig, DecodeStack, KvHandoff,
};
use crate::obs::{Candidate, Recorder};
use crate::traffic::admission::ThrottleConfig;
use crate::traffic::generator::TrafficGen;
use crate::traffic::phases;
use crate::traffic::router::{RoutePolicy, StackRouter};
use crate::util::json::Json;

/// Identifier for a stack architecture preset.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StackArchId {
    /// The HeTraX 3D stack — today's defaults, the exact identity arch.
    Hetrax3d,
    /// 2.5D chiplet sibling: larger SM tier, lower thermal ceiling,
    /// interposer-routed KV.
    Chiplet2p5d,
    /// Atleus edge stack: small tiers, tight ceiling, cheap idle.
    AtleusEdge,
}

impl StackArchId {
    /// Canonical CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            StackArchId::Hetrax3d => "hetrax3d",
            StackArchId::Chiplet2p5d => "chiplet2p5d",
            StackArchId::AtleusEdge => "atleus-edge",
        }
    }

    /// Parse a CLI name. Returns `None` for unknown names.
    pub fn parse(s: &str) -> Option<StackArchId> {
        match s {
            "hetrax3d" => Some(StackArchId::Hetrax3d),
            "chiplet2p5d" => Some(StackArchId::Chiplet2p5d),
            "atleus-edge" => Some(StackArchId::AtleusEdge),
            _ => None,
        }
    }

    /// All known presets, in CLI-listing order.
    pub fn all() -> &'static [StackArchId] {
        &[
            StackArchId::Hetrax3d,
            StackArchId::Chiplet2p5d,
            StackArchId::AtleusEdge,
        ]
    }

    /// The full descriptor for this preset.
    pub fn spec(&self) -> StackArch {
        StackArch::preset(*self)
    }
}

/// Architecture descriptor: how one stack differs from the HeTraX 3D
/// default. `None` overrides leave the base config untouched, so the
/// `hetrax3d` preset (all `None`, scales 1.0) is an exact identity —
/// required for the homogeneous-fleet byte-identity guarantee.
#[derive(Debug, Clone)]
pub struct StackArch {
    /// Which preset this descriptor came from.
    pub id: StackArchId,
    /// Relative steady-state decode throughput vs `hetrax3d` (ratio of SM
    /// counts). Routers divide queue pressure by this; 1.0 divides
    /// bitwise-exactly.
    pub compute_scale: f64,
    /// Multiplier on the KV pool's `capacity_bytes` (1.0 = unchanged).
    kv_capacity_scale: f64,
    /// Override for the KV pool's SM-tier fraction, if any.
    kv_sm_frac: Option<f64>,
    /// Thermal ceiling override in °C; applied as `min` with the user's
    /// ceiling so an explicitly tighter `--ceiling` survives.
    ceiling_c: Option<f64>,
    sm_mc_grid: Option<usize>,
    sm_count: Option<usize>,
    mc_count: Option<usize>,
    reram_grid: Option<usize>,
    reram_count: Option<usize>,
    tile_power_w: Option<f64>,
}

impl StackArch {
    /// Build the descriptor for a preset.
    pub fn preset(id: StackArchId) -> StackArch {
        match id {
            StackArchId::Hetrax3d => StackArch {
                id,
                compute_scale: 1.0,
                kv_capacity_scale: 1.0,
                kv_sm_frac: None,
                ceiling_c: None,
                sm_mc_grid: None,
                sm_count: None,
                mc_count: None,
                reram_grid: None,
                reram_count: None,
                tile_power_w: None,
            },
            // Larger SM tier on an interposer: 40 SM + 8 MC fill three
            // 4x4 tiers; more KV capacity but a lower ceiling (the
            // interposer spreads less heat than a 3D TSV field), and a
            // bigger share of KV parked off the SM tier.
            StackArchId::Chiplet2p5d => StackArch {
                id,
                compute_scale: 40.0 / 21.0,
                kv_capacity_scale: 1.5,
                kv_sm_frac: Some(0.25),
                ceiling_c: Some(52.0),
                sm_mc_grid: Some(4),
                sm_count: Some(40),
                mc_count: Some(8),
                reram_grid: None,
                reram_count: None,
                tile_power_w: None,
            },
            // Edge stack: 9 SM + 3 MC on 2x2 tiers, a 2x2 ReRAM tier,
            // half the KV budget, tight ceiling, cheap idle.
            StackArchId::AtleusEdge => StackArch {
                id,
                compute_scale: 9.0 / 21.0,
                kv_capacity_scale: 0.5,
                kv_sm_frac: None,
                ceiling_c: Some(50.0),
                sm_mc_grid: Some(2),
                sm_count: Some(9),
                mc_count: Some(3),
                reram_grid: Some(2),
                reram_count: Some(4),
                tile_power_w: Some(0.20),
            },
        }
    }

    /// Apply the architecture's tier-layout overrides to a base config.
    /// For `hetrax3d` this is `base.clone()` exactly.
    pub fn config(&self, base: &Config) -> Config {
        let mut cfg = base.clone();
        if let Some(g) = self.sm_mc_grid {
            cfg.sm_mc_grid = g;
        }
        if let Some(n) = self.sm_count {
            cfg.sm_count = n;
        }
        if let Some(n) = self.mc_count {
            cfg.mc_count = n;
        }
        if let Some(g) = self.reram_grid {
            cfg.reram_grid = g;
        }
        if let Some(n) = self.reram_count {
            cfg.reram_count = n;
        }
        if let Some(w) = self.tile_power_w {
            cfg.tile_power_w = w;
        }
        cfg.validate()
            .expect("arch preset must produce a valid config");
        cfg
    }

    /// Scale the KV pool config. `kv_capacity_scale == 1.0` multiplies
    /// bitwise-identically, so `hetrax3d` leaves the pool untouched.
    pub fn kv_config(&self, base: KvCacheConfig) -> KvCacheConfig {
        let mut kv = base;
        kv.capacity_bytes *= self.kv_capacity_scale;
        if let Some(f) = self.kv_sm_frac {
            kv.sm_frac = f;
        }
        kv
    }

    /// Clamp the throttle ceiling to the architecture's thermal limit.
    /// Uses `min`, not replacement: an explicitly tighter user ceiling
    /// survives an arch with a looser one.
    pub fn throttle(&self, base: ThrottleConfig) -> ThrottleConfig {
        let mut th = base;
        if let Some(c) = self.ceiling_c {
            th.ceiling_c = th.ceiling_c.min(c);
        }
        th
    }
}

/// Resolve a per-stack arch spec against a fleet size. An empty spec means
/// "all hetrax3d"; a single entry broadcasts; otherwise the list must
/// match the stack count (CLI-validated; debug-asserted here) and cycling
/// keeps release builds total.
pub fn resolve_archs(archs: &[StackArchId], stacks: usize) -> Vec<StackArchId> {
    if archs.is_empty() {
        return vec![StackArchId::Hetrax3d; stacks];
    }
    if archs.len() == 1 {
        return vec![archs[0]; stacks];
    }
    debug_assert_eq!(archs.len(), stacks, "arch list must match stack count");
    archs.iter().copied().cycle().take(stacks).collect()
}

/// Interposer-class link bandwidth in bytes/s, derived from the NoC flit
/// width and clock the energy model already uses: one 128-bit flit per
/// cycle at 1 GHz = 16 GB/s.
pub fn interposer_bw_bps() -> f64 {
    specs::NOC_FLIT_BITS as f64 * specs::NOC_CLOCK_HZ / 8.0
}

/// Energy to move `bytes` across the interposer, in joules: flit count ×
/// (router energy + per-mm link energy × one tier edge). Zero for
/// non-positive byte counts, so folding it into an energy total is a
/// bitwise no-op when nothing was transferred.
pub fn transfer_energy_j(bytes: f64) -> f64 {
    if bytes <= 0.0 {
        return 0.0;
    }
    let flits = (bytes * 8.0 / specs::NOC_FLIT_BITS as f64).ceil();
    let pj_per_flit = specs::NOC_ROUTER_PJ_PER_FLIT
        + specs::NOC_LINK_PJ_PER_FLIT_PER_MM * specs::TIER_SIZE_MM;
    flits * pj_per_flit * 1.0e-12
}

/// Config for a disaggregated fleet run.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Base decode config (stacks, policy, traffic, per-stack archs).
    pub dc: DecodeConfig,
    /// How many stacks (from index 0) are prefill-specialized. Clamped to
    /// `[1, stacks - 1]`.
    pub prefill_stacks: usize,
    /// KV transfer bandwidth override in bytes/s. `None` uses
    /// [`interposer_bw_bps`]; `f64::INFINITY` models a free hand-off
    /// (used by the equivalence tests).
    pub transfer_bw_bps: Option<f64>,
    /// Optional `(t_s, stack)` crash injection, for the fault-interplay
    /// path: the stack dies at `t_s`, surrendering its queue.
    pub crash: Option<(f64, usize)>,
}

/// Double-entry ledger for a disaggregated run. Every request and every
/// hand-off is accounted exactly once; [`FleetOutcome::conserved`] checks
/// the identities against the merged stack outcomes.
#[derive(Debug, Clone)]
pub struct FleetOutcome {
    /// Requests that arrived from the trace.
    pub arrived: u64,
    /// Pushes into prefill stacks (arrivals + crash re-queues that found
    /// a route).
    pub pushes: u64,
    /// Arrivals or re-queues that found no live prefill stack.
    pub no_route: u64,
    /// Crash survivors successfully re-routed to another prefill stack.
    pub requeued: u64,
    /// Requests surrendered by a crashing stack.
    pub surrendered: u64,
    /// Stacks crashed.
    pub crashes: u64,
    /// Hand-offs delivered to a decode stack.
    pub delivered: u64,
    /// Hand-offs with no live decode stack to go to.
    pub undeliverable: u64,
    /// Completions observed on prefill stacks (single-token prefills).
    pub completions_prefill: u64,
    /// Prefill completions whose original budget exceeded one token and
    /// therefore needed a hand-off.
    pub handoff_candidates: u64,
    /// Total KV bytes shipped across the interconnect.
    pub transferred_kv_bytes: f64,
    /// Total wire time charged, in seconds.
    pub transfer_s_total: f64,
    /// Resolved per-stack architectures.
    pub archs: Vec<StackArchId>,
    /// Resolved prefill stack count.
    pub prefill_stacks: usize,
}

impl FleetOutcome {
    /// Double-entry conservation against the merged stack outcomes:
    /// everything submitted to a stack was a push or a delivery; every
    /// submission completed, shed, or was refused; every hand-off
    /// candidate was delivered or declared undeliverable; every arrival
    /// or surrendered request was pushed or failed to route.
    pub fn conserved(
        &self,
        submitted: u64,
        completed: u64,
        shed: u64,
        refused: u64,
    ) -> bool {
        submitted == self.pushes + self.delivered
            && completed + shed + refused == submitted
            && self.handoff_candidates == self.delivered + self.undeliverable
            && self.arrived + self.surrendered == self.pushes + self.no_route
    }

    /// Logical end-to-end completions: the merged `completed` counts a
    /// handed-off request twice (once at prefill, once at decode), so
    /// subtract the hand-off candidates.
    pub fn completed_logical(&self, merged_completed: u64) -> u64 {
        merged_completed - self.handoff_candidates.min(merged_completed)
    }

    /// Ledger as JSON rows.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("arrived", self.arrived)
            .set("pushes", self.pushes)
            .set("no_route", self.no_route)
            .set("requeued", self.requeued)
            .set("surrendered", self.surrendered)
            .set("crashes", self.crashes)
            .set("prefill_stacks", self.prefill_stacks)
            .set("completions_prefill", self.completions_prefill)
            .set("handoff_candidates", self.handoff_candidates)
            .set("delivered", self.delivered)
            .set("undeliverable", self.undeliverable)
            .set(
                "transferred_kv_mib",
                self.transferred_kv_bytes / (1024.0 * 1024.0),
            )
            .set("transfer_s_total", self.transfer_s_total)
            .set(
                "archs",
                self.archs.iter().map(|a| a.name()).collect::<Vec<_>>(),
            );
        j
    }
}

/// Per-architecture utilization/throughput rollup from a fleet report:
/// one row per distinct arch (first-seen order), averaging utilization
/// and summing completions/tokens/energy over that arch's stacks.
pub fn per_arch_json(report: &DecodeReport, archs: &[StackArchId]) -> Json {
    let mut order: Vec<StackArchId> = Vec::new();
    for a in archs {
        if !order.contains(a) {
            order.push(*a);
        }
    }
    let rows = order
        .iter()
        .map(|arch| {
            let group: Vec<usize> = archs
                .iter()
                .enumerate()
                .filter(|(_, a)| *a == *arch)
                .map(|(i, _)| i)
                .take(report.stacks.len())
                .collect();
            let mut completed = 0u64;
            let mut tokens = 0u64;
            let mut sm_busy = 0.0f64;
            let mut reram_busy = 0.0f64;
            let mut energy = 0.0f64;
            for &i in &group {
                let t = &report.stacks[i].telemetry;
                completed += t.completed;
                tokens += t.tokens_out;
                sm_busy += t.sm_busy_s;
                reram_busy += t.reram_busy_s;
                energy += t.energy_j;
            }
            let span = report.total.makespan_s * group.len() as f64;
            let util = |busy: f64| if span > 0.0 { busy / span } else { 0.0 };
            let mut row = Json::obj();
            row.set("arch", arch.name())
                .set("stacks", group.len())
                .set("completed", completed)
                .set("tokens", tokens)
                .set("sm_util", util(sm_busy))
                .set("reram_util", util(reram_busy))
                .set("energy_j", energy);
            row
        })
        .collect();
    Json::Arr(rows)
}

/// Fresh snapshots of every stack, in index order.
fn snaps_of(stacks: &[DecodeStack<'_>]) -> Vec<StackSnapshot> {
    stacks
        .iter()
        .enumerate()
        .map(|(i, s)| s.snapshot(i))
        .collect()
}

/// Route a batch of prefill completions to decode stacks. Completions are
/// sorted by `(finish_s, id)` so delivery order — and therefore the
/// KV-aware router's view — is deterministic regardless of which stack
/// finished which prefill. Each completion *consumes* its `orig_out`
/// entry (a prefill completes at most once per request id — a crash
/// surrenders queued work before it can complete), so the budget map
/// stays O(in-flight) on streamed runs instead of O(arrivals).
#[allow(clippy::too_many_arguments)]
fn deliver_handoffs(
    mut completions: Vec<Completion>,
    orig_out: &mut HashMap<u64, usize>,
    stacks: &mut [DecodeStack<'_>],
    engine: &DecodeEngine<'_>,
    router: &StackRouter,
    routable: &[bool],
    bw: f64,
    handoff_seq: &mut u64,
    rec: &Recorder,
    out: &mut FleetOutcome,
) {
    completions.sort_by(|a, b| {
        a.finish_s
            .partial_cmp(&b.finish_s)
            .unwrap()
            .then(a.id.cmp(&b.id))
    });
    for c in completions {
        out.completions_prefill += 1;
        let budget = orig_out.remove(&c.id).unwrap_or(1);
        if budget <= 1 {
            // Single-token request: the prefill emission IS the answer.
            continue;
        }
        out.handoff_candidates += 1;
        let dw = engine.workload(c.model, c.variant);
        // The KV produced by the prefill: prompt tokens + the one token
        // the prefill stack generated.
        let kv_bytes = dw.kv_bytes(c.prompt, 1);
        let transfer_s = if bw.is_finite() { kv_bytes / bw } else { 0.0 };
        let need = dw.peak_kv_bytes(c.prompt, budget);
        let snaps = snaps_of(stacks);
        let pick = router.choose_masked(*handoff_seq, c.finish_s, &snaps, need, routable);
        *handoff_seq += 1;
        rec.handoff_routed(c.finish_s, c.id, pick, kv_bytes, transfer_s);
        match pick {
            Some(target) => {
                stacks[target].push_handoff(KvHandoff {
                    id: c.id,
                    model: c.model,
                    variant: c.variant,
                    prompt: c.prompt,
                    arrival_s: c.arrival_s,
                    first_token_s: c.first_token_s,
                    ready_s: c.finish_s + transfer_s,
                    kv_bytes,
                    transfer_s,
                    out_tokens: budget,
                });
                out.delivered += 1;
                out.transferred_kv_bytes += kv_bytes;
                out.transfer_s_total += transfer_s;
            }
            None => out.undeliverable += 1,
        }
    }
}

/// Crash one stack at `t_c`: step the fleet to the crash instant, deliver
/// any completions that beat the crash, then surrender the victim's queue
/// and re-route survivors to the remaining live prefill stacks at
/// single-token budget (their original budget is still in `orig_out`, so
/// a re-run prefill hands off normally).
#[allow(clippy::too_many_arguments)]
fn crash_stack(
    victim: usize,
    t_c: f64,
    stacks: &mut [DecodeStack<'_>],
    alive: &mut [bool],
    prefill_mask: &[bool],
    engine: &DecodeEngine<'_>,
    arrival_router: &StackRouter,
    handoff_router: &StackRouter,
    orig_out: &mut HashMap<u64, usize>,
    bw: f64,
    handoff_seq: &mut u64,
    rec: &Recorder,
    out: &mut FleetOutcome,
) {
    let n = stacks.len();
    for s in stacks.iter_mut() {
        s.step_until(t_c);
    }
    let mut pre_crash: Vec<Completion> = Vec::new();
    for i in 0..out.prefill_stacks.min(n) {
        pre_crash.extend(stacks[i].drain_completions());
    }
    // Mark the victim dead BEFORE building the delivery mask: a hand-off
    // must never land on the stack that is crashing at this instant.
    alive[victim] = false;
    out.crashes += 1;
    rec.fault(t_c, victim, "crash");
    rec.health(t_c, victim, HealthState::Dead.name());
    let decode_mask: Vec<bool> = (0..n)
        .map(|i| !prefill_mask[i] && alive[i])
        .collect();
    deliver_handoffs(
        pre_crash, orig_out, stacks, engine, handoff_router, &decode_mask, bw,
        handoff_seq, rec, out,
    );
    let surrendered = stacks[victim].fail(t_c);
    out.surrendered += surrendered.len() as u64;
    let route_mask: Vec<bool> = (0..n)
        .map(|i| prefill_mask[i] && alive[i])
        .collect();
    for r in surrendered {
        let mut retry = r;
        retry.out_tokens = 1;
        retry.input = None;
        let need = engine
            .workload(retry.model, retry.variant)
            .peak_kv_bytes(retry.seq, 1);
        let snaps = snaps_of(stacks);
        let pick =
            arrival_router.choose_masked(*handoff_seq, t_c, &snaps, need, &route_mask);
        *handoff_seq += 1;
        if rec.enabled() {
            // One hop per surrendered request: re-arrives immediately at
            // the crash instant (no backoff on the fleet path).
            rec.retry(t_c, retry.id, 1, t_c);
            let candidates: Vec<Candidate> = snaps
                .iter()
                .map(|s| Candidate {
                    stack: s.stack,
                    key: arrival_router.rank_key(s, t_c, need),
                    routable: route_mask.get(s.stack).copied().unwrap_or(false),
                })
                .collect();
            rec.route(t_c, retry.id, arrival_router.policy.name(), pick, candidates);
        }
        match pick {
            Some(target) => {
                stacks[target].push(retry);
                out.pushes += 1;
                out.requeued += 1;
            }
            None => out.no_route += 1,
        }
    }
}

/// Run a disaggregated fleet: prefill-specialized stacks serve arrivals at
/// a single-token budget, hand their KV to decode-specialized stacks over
/// the interconnect, and the merged report aggregates both halves.
///
/// Returns the merged [`DecodeReport`] plus the fleet ledger. See the
/// module docs for the per-arrival event ordering.
pub fn run_disaggregated(cfg: &Config, fc: &FleetConfig) -> (DecodeReport, FleetOutcome) {
    run_disaggregated_traced(cfg, fc, &Recorder::Off)
}

/// [`run_disaggregated`] with an observability recorder threaded through
/// the driver and every stack. With [`Recorder::Off`] this *is*
/// `run_disaggregated` (one discriminant branch per hook); with a live
/// recorder the report and ledger are unchanged and the trace captures
/// arrivals, route decisions, hand-off routing and joins, crash faults,
/// retry hops, and every lifecycle terminal.
pub fn run_disaggregated_traced(
    cfg: &Config,
    fc: &FleetConfig,
    rec: &Recorder,
) -> (DecodeReport, FleetOutcome) {
    let dc = &fc.dc;
    assert!(dc.stacks >= 2, "disaggregation needs at least 2 stacks");
    let n = dc.stacks;
    let pn = fc.prefill_stacks.clamp(1, n - 1);
    let bw = fc.transfer_bw_bps.unwrap_or_else(interposer_bw_bps);

    let generator = TrafficGen {
        pattern: dc.pattern.clone(),
        mix: dc.mix.clone(),
        seed: dc.seed,
    };
    // Streamed runs (`stream_chunk > 0`, the default) never materialize
    // the arrival vector: the driver below is one-arrival-at-a-time
    // already, so the stream feeds it directly and the phase tables and
    // engines come from the generator's stream-length-independent key
    // superset. 0 keeps the legacy whole-stream materialization.
    let streaming = dc.stream_chunk > 0;
    let requests: Vec<crate::coordinator::Request> =
        if streaming { Vec::new() } else { generator.generate(dc.duration_s) };
    let threads = crate::util::pool::resolve_threads(dc.threads);

    let archs = resolve_archs(&dc.archs, n);
    let mut distinct: Vec<StackArchId> = Vec::new();
    for a in &archs {
        if !distinct.contains(a) {
            distinct.push(*a);
        }
    }
    // Per-distinct-arch configs, phase tables, and engines. Declared
    // before the stacks so the borrows outlive them.
    let cfgs: Vec<Config> = distinct.iter().map(|a| a.spec().config(cfg)).collect();
    let keys = if streaming { generator.decode_keys() } else { phases::decode_keys(&requests) };
    let candidates: Vec<phases::PhaseKey> = if streaming {
        generator.phase_keys()
    } else {
        requests.iter().map(|r| (r.model, r.variant, r.seq)).collect()
    };
    let tables: Vec<_> = cfgs
        .iter()
        .map(|c| phases::phase_table_for_keys(c, &candidates, dc.chunk_tokens, threads))
        .collect();
    let engines: Vec<DecodeEngine<'_>> = cfgs
        .iter()
        .map(|c| DecodeEngine::build(c, &keys))
        .collect();

    let mut stacks: Vec<DecodeStack<'_>> = archs
        .iter()
        .enumerate()
        .map(|(i, a)| {
            let di = distinct.iter().position(|d| d == a).unwrap();
            let mut s =
                DecodeStack::with_arch(&cfgs[di], dc, &tables[di], &engines[di], &a.spec());
            if rec.enabled() {
                let role = if i < pn { "prefill" } else { "decode" };
                rec.stack_label(i, format!("stack {i} ({} {role})", a.name()));
                s.attach_obs(rec.clone(), i);
            }
            s
        })
        .collect();
    for s in stacks.iter_mut().take(pn) {
        s.record_completions(true);
    }

    let prefill_mask: Vec<bool> = (0..n).map(|i| i < pn).collect();
    let mut alive = vec![true; n];
    let arrival_router = StackRouter::new(n, dc.policy);
    // Hand-offs are always routed KV-aware: the whole point of choosing
    // the decode target at hand-off time is placing the KV bytes well.
    let handoff_router = StackRouter::new(n, RoutePolicy::KvAware);
    // KV byte accounting uses the first engine's workload; the decode
    // workload's byte geometry is arch-independent (archs change tier
    // layout and budgets, not the model's KV row size).
    let account_engine = &engines[0];

    let mut out = FleetOutcome {
        arrived: 0,
        pushes: 0,
        no_route: 0,
        requeued: 0,
        surrendered: 0,
        crashes: 0,
        delivered: 0,
        undeliverable: 0,
        completions_prefill: 0,
        handoff_candidates: 0,
        transferred_kv_bytes: 0.0,
        transfer_s_total: 0.0,
        archs: archs.clone(),
        prefill_stacks: pn,
    };
    let mut orig_out: HashMap<u64, usize> = HashMap::new();
    let mut handoff_seq: u64 = 0;
    let mut crash = fc.crash;

    // The owned-arrival iterator: the seeded stream (O(1) memory) or the
    // materialized vector, depending on the knob. Both feed the same
    // per-arrival body, so the results are byte-identical.
    let arrivals: Box<dyn Iterator<Item = crate::coordinator::Request>> = if streaming {
        Box::new(generator.stream(dc.duration_s))
    } else {
        Box::new(requests.into_iter())
    };
    for (i, req) in arrivals.enumerate() {
        let t = req.arrival_s;
        if let Some((t_c, victim)) = crash {
            if t_c <= t && victim < n && alive[victim] {
                crash_stack(
                    victim, t_c, &mut stacks, &mut alive, &prefill_mask,
                    account_engine, &arrival_router, &handoff_router, &mut orig_out,
                    bw, &mut handoff_seq, rec, &mut out,
                );
                crash = None;
            }
        }
        for s in stacks.iter_mut() {
            s.step_until(t);
        }
        let mut done: Vec<Completion> = Vec::new();
        for s in stacks.iter_mut().take(pn) {
            done.extend(s.drain_completions());
        }
        let decode_mask: Vec<bool> = (0..n)
            .map(|j| !prefill_mask[j] && alive[j])
            .collect();
        deliver_handoffs(
            done, &mut orig_out, &mut stacks, account_engine, &handoff_router,
            &decode_mask, bw, &mut handoff_seq, rec, &mut out,
        );

        out.arrived += 1;
        rec.arrival(t, req.id);
        orig_out.insert(req.id, req.out_tokens.max(1));
        let mut prefill_req = req.clone();
        prefill_req.out_tokens = 1;
        let need = account_engine
            .workload(req.model, req.variant)
            .peak_kv_bytes(req.seq, 1);
        let route_mask: Vec<bool> = (0..n)
            .map(|j| prefill_mask[j] && alive[j])
            .collect();
        let snaps = snaps_of(&stacks);
        let pick = arrival_router.choose_masked(i as u64, t, &snaps, need, &route_mask);
        if rec.enabled() {
            let candidates: Vec<Candidate> = snaps
                .iter()
                .map(|s| Candidate {
                    stack: s.stack,
                    key: arrival_router.rank_key(s, t, need),
                    routable: route_mask.get(s.stack).copied().unwrap_or(false),
                })
                .collect();
            rec.route(t, req.id, arrival_router.policy.name(), pick, candidates);
        }
        match pick {
            Some(target) => {
                stacks[target].push(prefill_req);
                out.pushes += 1;
            }
            None => out.no_route += 1,
        }
    }

    // Stream over. Fire any still-pending crash, then drain the prefill
    // side to completion and deliver the final wave of hand-offs.
    if let Some((t_c, victim)) = crash {
        if victim < n && alive[victim] {
            crash_stack(
                victim, t_c, &mut stacks, &mut alive, &prefill_mask,
                account_engine, &arrival_router, &handoff_router, &mut orig_out,
                bw, &mut handoff_seq, rec, &mut out,
            );
        }
    }
    for s in stacks.iter_mut().take(pn) {
        s.run_to_completion();
    }
    let mut done: Vec<Completion> = Vec::new();
    for s in stacks.iter_mut().take(pn) {
        done.extend(s.drain_completions());
    }
    let decode_mask: Vec<bool> = (0..n)
        .map(|j| !prefill_mask[j] && alive[j])
        .collect();
    deliver_handoffs(
        done, &mut orig_out, &mut stacks, account_engine, &handoff_router,
        &decode_mask, bw, &mut handoff_seq, rec, &mut out,
    );

    // Post-stream drain: hand-offs are all delivered by now, so the
    // per-stack `finish()` calls are independent and fan out across
    // workers — except under a live recorder, where the serial drain
    // keeps the trace's window-event order. (The per-arrival stepping
    // above stays linear: prefill→decode hand-off delivery couples the
    // stacks, so there is no idle set to skip.)
    let outcomes = if rec.enabled() {
        stacks.into_iter().map(DecodeStack::finish).collect()
    } else {
        crate::util::pool::par_map_owned(stacks, threads, DecodeStack::finish)
    };
    let report = decodetest::aggregate(dc, outcomes);
    (report, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelId;
    use crate::traffic::generator::{ArrivalPattern, ReplayEvent, RequestMix};

    fn replay(n: usize, out_tokens: usize) -> Vec<ReplayEvent> {
        (0..n)
            .map(|i| ReplayEvent {
                t_s: i as f64 * 0.001,
                model: ModelId::BertBase,
                variant: ModelId::BertBase.default_variant(),
                seq: 512,
                out_tokens,
            })
            .collect()
    }

    fn fleet_dc(stacks: usize, events: &[ReplayEvent]) -> DecodeConfig {
        let mix = RequestMix::single(ModelId::BertBase);
        let mut dc = DecodeConfig::new(
            ArrivalPattern::Replay { events: events.to_vec() },
            mix,
        );
        dc.stacks = stacks;
        dc.policy = RoutePolicy::KvAware;
        dc.max_running = 8;
        dc.kv.capacity_bytes = 1024.0 * 1024.0 * 1024.0;
        dc
    }

    #[test]
    fn presets_validate_and_hetrax3d_is_identity() {
        let base = Config::default();
        for id in StackArchId::all() {
            let arch = id.spec();
            // config() panics internally if the preset is inconsistent.
            let cfg = arch.config(&base);
            assert!(cfg.sm_mc_tiers == base.sm_mc_tiers, "presets keep 3 tiers");
        }
        let identity = StackArchId::Hetrax3d.spec();
        assert_eq!(identity.config(&base), base);
        let kv = KvCacheConfig::default();
        let kv2 = identity.kv_config(kv);
        assert!(kv2.capacity_bytes == kv.capacity_bytes);
        assert!(kv2.sm_frac == kv.sm_frac);
        let th = ThrottleConfig::default();
        let th2 = identity.throttle(th);
        assert!(th2.ceiling_c == th.ceiling_c);
        assert!(identity.compute_scale == 1.0);
    }

    #[test]
    fn arch_names_roundtrip_and_reject_junk() {
        for id in StackArchId::all() {
            assert_eq!(StackArchId::parse(id.name()), Some(*id));
        }
        assert_eq!(StackArchId::parse("tpu"), None);
        assert_eq!(StackArchId::parse(""), None);
        assert_eq!(StackArchId::parse("Hetrax3d"), None);
    }

    #[test]
    fn transfer_model_matches_noc_constants() {
        assert!(interposer_bw_bps() == 16.0e9);
        assert!(transfer_energy_j(0.0) == 0.0);
        assert!(transfer_energy_j(-5.0) == 0.0);
        let one_flit = transfer_energy_j(16.0); // 128 bits
        assert!(one_flit > 0.0);
        assert!(transfer_energy_j(32.0) > one_flit);
        // 16 bytes = exactly one flit: router + link across one tier edge.
        let expected = (4.0 + 12.8 * 10.0) * 1.0e-12;
        assert!((one_flit - expected).abs() < 1e-18);
    }

    #[test]
    fn zero_cost_transfer_pins_disaggregated_against_monolithic() {
        let events = replay(16, 16);
        let dc = fleet_dc(2, &events);
        let cfg = Config::default();
        let mono = decodetest::run(&cfg, &dc);
        let fc = FleetConfig {
            dc: dc.clone(),
            prefill_stacks: 1,
            transfer_bw_bps: Some(f64::INFINITY),
            crash: None,
        };
        let (report, out) = run_disaggregated(&cfg, &fc);
        assert_eq!(out.arrived, 16);
        assert_eq!(out.no_route, 0);
        assert_eq!(out.undeliverable, 0);
        assert_eq!(out.delivered, out.handoff_candidates);
        assert!(out.conserved(
            report.total.submitted,
            report.total.completed,
            report.total.shed,
            report.total.refused_kv,
        ));
        // Token parity: prefill emits 1 of each request's 16, decode the
        // other 15 — logically identical to the monolithic run.
        assert_eq!(report.total.tokens_out, mono.total.tokens_out);
        assert_eq!(
            out.completed_logical(report.total.completed),
            mono.total.completed
        );
        assert!(out.transferred_kv_bytes > 0.0);
        assert!(out.transfer_s_total == 0.0);
    }

    #[test]
    fn disaggregated_is_deterministic_across_runs_and_threads() {
        let events = replay(24, 12);
        let doc = |threads: usize| {
            let mut dc = fleet_dc(3, &events);
            dc.threads = threads;
            dc.archs = vec![StackArchId::Hetrax3d];
            let fc = FleetConfig {
                dc,
                prefill_stacks: 2,
                transfer_bw_bps: None,
                crash: None,
            };
            let (report, out) = run_disaggregated(&Config::default(), &fc);
            format!(
                "{}\n{}",
                report.to_json(&fc.dc).pretty(),
                out.to_json().pretty()
            )
        };
        let a = doc(1);
        let b = doc(1);
        let c = doc(4);
        assert_eq!(a, b, "same-thread reruns must be byte-identical");
        assert_eq!(a, c, "thread count must not leak into results");
    }

    #[test]
    fn heterogeneous_fleet_serves_and_rolls_up_per_arch() {
        let events = replay(18, 8);
        let mut dc = fleet_dc(3, &events);
        dc.archs = vec![
            StackArchId::Chiplet2p5d,
            StackArchId::Hetrax3d,
            StackArchId::Hetrax3d,
        ];
        let fc = FleetConfig {
            dc,
            prefill_stacks: 1,
            transfer_bw_bps: None,
            crash: None,
        };
        let (report, out) = run_disaggregated(&Config::default(), &fc);
        assert!(out.conserved(
            report.total.submitted,
            report.total.completed,
            report.total.shed,
            report.total.refused_kv,
        ));
        assert!(report.total.tokens_out > 0);
        let rollup = per_arch_json(&report, &out.archs);
        match &rollup {
            Json::Arr(rows) => assert_eq!(rows.len(), 2, "two distinct archs"),
            _ => panic!("per_arch_json must be an array"),
        }
        // Determinism holds for heterogeneous fleets too.
        let (report2, out2) = run_disaggregated(&Config::default(), &fc);
        assert_eq!(
            report.to_json(&fc.dc).pretty(),
            report2.to_json(&fc.dc).pretty()
        );
        assert_eq!(out.to_json().pretty(), out2.to_json().pretty());
    }

    #[test]
    fn streamed_fleet_is_byte_identical_to_materialized() {
        // The disaggregated driver fed by the bounded stream (several
        // chunk sizes) must reproduce the materialized run byte for
        // byte — report and ledger — including across a mid-stream
        // crash, where the budget map is consumed on delivery.
        let events = replay(20, 8);
        let doc = |chunk: usize| {
            let mut dc = fleet_dc(3, &events);
            dc.stream_chunk = chunk;
            let fc = FleetConfig {
                dc,
                prefill_stacks: 2,
                transfer_bw_bps: None,
                crash: Some((0.008, 0)),
            };
            let (r, o) = run_disaggregated(&Config::default(), &fc);
            format!("{}\n{}", r.to_json(&fc.dc).pretty(), o.to_json().pretty())
        };
        let materialized = doc(0);
        for chunk in [1usize, 64, 1024] {
            assert_eq!(doc(chunk), materialized, "chunk {chunk} diverged");
        }
    }

    #[test]
    fn prefill_crash_reroutes_handoffs_and_conserves() {
        let events = replay(20, 8);
        let dc = fleet_dc(3, &events);
        let fc = FleetConfig {
            dc,
            prefill_stacks: 2,
            transfer_bw_bps: None,
            crash: Some((0.008, 0)),
        };
        let (report, out) = run_disaggregated(&Config::default(), &fc);
        assert_eq!(out.crashes, 1);
        assert!(out.surrendered > 0, "crash mid-wave must surrender work");
        assert!(out.requeued > 0 || out.no_route > 0);
        assert!(out.conserved(
            report.total.submitted,
            report.total.completed,
            report.total.shed,
            report.total.refused_kv,
        ));
        // Survivors re-ran on the other prefill stack and still handed off.
        assert!(out.delivered > 0);
        let (report2, out2) = run_disaggregated(&Config::default(), &fc);
        assert_eq!(
            report.to_json(&fc.dc).pretty(),
            report2.to_json(&fc.dc).pretty()
        );
        assert_eq!(out.to_json().pretty(), out2.to_json().pretty());
    }

    #[test]
    fn traced_disaggregated_crash_run_reconstructs_and_reproduces() {
        use crate::obs::{inspect, Event, Outcome, Recorder};
        let events = replay(20, 8);
        let fc_of = |threads: usize| {
            let mut dc = fleet_dc(3, &events);
            dc.threads = threads;
            FleetConfig {
                dc,
                prefill_stacks: 2,
                transfer_bw_bps: None,
                crash: Some((0.008, 0)),
            }
        };

        // The recorder must not perturb the simulation.
        let fc = fc_of(1);
        let (plain_report, plain_out) = run_disaggregated(&Config::default(), &fc);
        let rec = Recorder::on();
        let (report, out) = run_disaggregated_traced(&Config::default(), &fc, &rec);
        assert_eq!(
            plain_report.to_json(&fc.dc).pretty(),
            report.to_json(&fc.dc).pretty(),
            "tracing must not change the report"
        );
        assert_eq!(plain_out.to_json().pretty(), out.to_json().pretty());

        // Trace and metrics are byte-identical across runs and thread counts.
        let trace_of = |threads: usize| {
            let r = Recorder::on();
            let fc = fc_of(threads);
            run_disaggregated_traced(&Config::default(), &fc, &r);
            (
                r.trace_json().expect("recorder on").pretty(),
                r.metrics_jsonl().expect("recorder on"),
            )
        };
        let (t1, m1) = trace_of(1);
        let (t1b, m1b) = trace_of(1);
        let (t4, m4) = trace_of(4);
        assert_eq!(t1, t1b, "trace must be byte-identical across reruns");
        assert_eq!(t1, t4, "trace must be byte-identical across thread counts");
        assert_eq!(m1, m1b);
        assert_eq!(m1, m4);

        // Double-entry: event counts agree exactly with conservation counters.
        rec.with_buf(|b| {
            let count = |f: &dyn Fn(&Event) -> bool| {
                b.events.iter().filter(|&e| f(e)).count() as u64
            };
            assert_eq!(count(&|e| matches!(e, Event::Arrival { .. })), out.arrived);
            assert_eq!(
                count(&|e| matches!(e, Event::HandoffRouted { to: Some(_), .. })),
                out.delivered
            );
            assert_eq!(
                count(&|e| matches!(e, Event::HandoffRouted { to: None, .. })),
                out.undeliverable
            );
            assert_eq!(
                count(&|e| matches!(e, Event::HandoffJoin { .. })),
                out.delivered
            );
            assert_eq!(count(&|e| matches!(e, Event::Retry { .. })), out.surrendered);
            assert_eq!(
                count(&|e| matches!(e, Event::Fault { kind: "crash", .. })),
                out.crashes
            );
            assert_eq!(
                count(&|e| matches!(
                    e,
                    Event::Terminal { outcome: Outcome::Completed, .. }
                )),
                report.total.completed,
            );
            assert_eq!(
                count(&|e| matches!(e, Event::Terminal { outcome: Outcome::Shed, .. })),
                report.total.shed,
            );
            assert_eq!(
                count(&|e| matches!(
                    e,
                    Event::Terminal { outcome: Outcome::RefusedKv, .. }
                )),
                report.total.refused_kv,
            );
        })
        .expect("recorder on");

        // Every arrival reconstructs to a closed lifecycle in the trace.
        let trace = rec.trace_json().expect("recorder on");
        let rows = inspect::request_table(&trace).expect("well-formed trace");
        assert_eq!(rows.len() as u64, out.arrived);
        assert!(
            rows.iter().all(|r| r.outcome != "open"),
            "every request must reach a terminal state"
        );
    }
}
