//! Cluster scale bench: the PR 9 tentpole acceptance. Sweeps cluster
//! size N at a fixed offered load and times the full lockstep serve
//! (route + step + drain) under the indexed next-event stepper with
//! JSQ(d) snapshot sampling, against the retained linear oracle.
//!
//! The claim under test is *near-linear in events, not N×events*: at a
//! fixed arrival stream, growing the cluster from 8 to 1000 stacks must
//! not grow the per-event cost — the heap only steps stacks with work
//! due and the router only snapshots d sampled candidates, so idle
//! stacks are free. The linear oracle pays O(N) per arrival and is
//! timed alongside to show the gap. Asserts: event throughput at the
//! largest N within 2x of N=8 (indexed stepper); heap byte-identical to
//! the oracle at every N; output byte-identical across runs and thread
//! counts. Emits `BENCH_cluster_scale.json` (override the path via
//! `CLUSTER_SCALE_JSON`; cap the sweep via `CLUSTER_SCALE_MAX_N` — CI
//! smokes N ≤ 128; schema: DESIGN.md §Bench-Schemas).
//!
//! PR 10 adds the stream-length sweep: arrivals ∈ {10k, 100k, 1M} at a
//! fixed cluster, run through the bounded-chunk arrival stream with the
//! `util::mem` gauge installed. The claim is O(stacks + in-flight)
//! memory — `peak_mem_bytes` must stay within 1.5x of the 10k point
//! while per-event throughput stays within 2x. Cap the sweep via
//! `CLUSTER_SCALE_MAX_ARRIVALS` (CI smokes ≤ 100k).

use hetrax::cluster::Stepper;
use hetrax::config::Config;
use hetrax::decode::decodetest;
use hetrax::decode::DecodeConfig;
use hetrax::model::ModelId;
use hetrax::traffic::{ArrivalPattern, OutputLenDist, RequestMix, RoutePolicy};
use hetrax::util::bench::Bencher;
use hetrax::util::json::Json;
use hetrax::util::{mem, pool};

/// The peak-memory claim needs the counting allocator in this binary
/// (the library never installs it on its own).
#[global_allocator]
static ALLOC: mem::CountingAlloc = mem::CountingAlloc;

/// Fixed offered load: the datacenter regime (many mostly-idle stacks)
/// where indexed stepping pays off. Per-stack load falls as N grows.
const RPS: f64 = 2000.0;
const DURATION_S: f64 = 0.25;
const SAMPLE_D: usize = 4;

/// Stream-length sweep shape: a fixed mid-size cluster at a rate high
/// enough that 1M arrivals stay a tractable simulated duration.
const STREAM_N: usize = 64;
const STREAM_RPS: f64 = 20_000.0;

fn scenario(n: usize, stepper: Stepper) -> DecodeConfig {
    let mix = RequestMix::single(ModelId::BertBase)
        .with_output(OutputLenDist::Geometric { mean: 6.0 });
    let mut dc = DecodeConfig::new(ArrivalPattern::Poisson { rps: RPS }, mix);
    dc.duration_s = DURATION_S;
    dc.stacks = n;
    dc.policy = RoutePolicy::JoinShortestQueue;
    dc.seed = 0xCA1E;
    dc.threads = 1;
    dc.sample_d = SAMPLE_D;
    dc.stepper = stepper;
    dc
}

fn main() {
    let cfg = Config::default();
    let auto = pool::resolve_threads(0);
    let max_n: usize = std::env::var("CLUSTER_SCALE_MAX_N")
        .ok()
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(1000);

    let mut sizes: Vec<usize> =
        [8usize, 64, 256, 1000].into_iter().filter(|&n| n <= max_n).collect();
    if sizes.is_empty() {
        sizes.push(max_n.max(1));
    } else if *sizes.last().unwrap() < max_n {
        sizes.push(max_n);
    }

    let b = Bencher::quick();
    let mut rows: Vec<Json> = Vec::new();
    let mut events_per_s: Vec<(usize, f64)> = Vec::new();
    for &n in &sizes {
        let idx = scenario(n, Stepper::Indexed);
        let lin = scenario(n, Stepper::Linear);

        // The heap must be invisible in the output at every size.
        mem::reset_peak();
        let report = decodetest::run(&cfg, &idx);
        let peak_mem = mem::peak_bytes();
        let oracle = decodetest::run(&cfg, &lin);
        assert_eq!(
            report.to_json(&idx).pretty(),
            oracle.to_json(&lin).pretty(),
            "N={n}: indexed stepper diverged from the linear oracle"
        );
        let events = report.total.submitted;

        let t_idx = b.time(&format!("indexed  N={n:<5}"), || decodetest::run(&cfg, &idx));
        let t_lin = b.time(&format!("linear   N={n:<5}"), || decodetest::run(&cfg, &lin));
        let ev_s = events as f64 / t_idx.median_s();
        events_per_s.push((n, ev_s));

        let mut row = Json::obj();
        row.set("stacks", n)
            .set("rps", RPS)
            .set("events", events)
            .set("completed", report.total.completed)
            .set("indexed_median_s", t_idx.median_s())
            .set("linear_median_s", t_lin.median_s())
            .set("events_per_s", ev_s)
            .set("speedup_vs_linear", t_lin.median_s() / t_idx.median_s())
            .set("peak_mem_bytes", peak_mem);
        rows.push(row);
    }

    // The tentpole acceptance: near-linear in events, not N×events —
    // per-event throughput at the largest N within 2x of the smallest.
    let (n0, ev0) = events_per_s[0];
    let (n1, ev1) = *events_per_s.last().unwrap();
    println!(
        "\n  event throughput: N={n0} -> {:.0} events/s, N={n1} -> {:.0} events/s ({:.2}x)",
        ev0,
        ev1,
        ev0 / ev1
    );
    if n1 > n0 {
        assert!(
            ev1 >= 0.5 * ev0,
            "indexed stepper must hold per-event throughput within 2x \
             from N={n0} ({ev0:.0}/s) to N={n1} ({ev1:.0}/s)"
        );
    }

    // Determinism contract: the document is byte-identical across
    // repeated runs and across thread counts at the largest size.
    let doc_of = |threads: usize| {
        let mut dc = scenario(n1, Stepper::Indexed);
        dc.threads = threads;
        decodetest::run(&cfg, &dc).to_json(&dc).pretty()
    };
    let canonical = doc_of(1);
    assert_eq!(canonical, doc_of(1), "same config+seed must reproduce byte-identically");
    assert_eq!(canonical, doc_of(auto), "thread count must not change the output");

    // ---- Stream-length sweep (PR 10): memory flat as arrivals grow ----
    // Fixed cluster, growing stream: duration = arrivals / rate, served
    // through the default bounded-chunk arrival stream. With the stream
    // never materialized, peak live bytes are O(stacks + in-flight) and
    // must not follow the stream length.
    let max_arrivals: usize = std::env::var("CLUSTER_SCALE_MAX_ARRIVALS")
        .ok()
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(1_000_000);
    let mut lengths: Vec<usize> = [10_000usize, 100_000, 1_000_000]
        .into_iter()
        .filter(|&a| a <= max_arrivals)
        .collect();
    if lengths.is_empty() {
        lengths.push(max_arrivals.max(1));
    }

    let mut stream_rows: Vec<Json> = Vec::new();
    let mut stream_stats: Vec<(usize, f64, usize)> = Vec::new();
    for &arrivals in &lengths {
        let mut dc = scenario(STREAM_N, Stepper::Indexed);
        dc.pattern = ArrivalPattern::Poisson { rps: STREAM_RPS };
        dc.duration_s = arrivals as f64 / STREAM_RPS;
        // One timed run per length (a Bencher repeat at 1M arrivals
        // would dominate the whole bench); the gauge reads the phase's
        // high-water mark, so the single pass is the measurement.
        mem::reset_peak();
        let start = std::time::Instant::now();
        let report = decodetest::run(&cfg, &dc);
        let wall_s = start.elapsed().as_secs_f64();
        let peak = mem::peak_bytes();
        let ev_s = report.total.submitted as f64 / wall_s;
        println!(
            "  stream   A={arrivals:<8} {:>9} arrived  {:>8.2} MiB peak  {:.0} events/s",
            report.total.submitted,
            peak as f64 / (1024.0 * 1024.0),
            ev_s
        );
        let mut row = Json::obj();
        row.set("arrivals_target", arrivals)
            .set("arrived", report.total.submitted)
            .set("completed", report.total.completed)
            .set("stacks", STREAM_N)
            .set("rps", STREAM_RPS)
            .set("duration_s", dc.duration_s)
            .set("stream_chunk", dc.stream_chunk)
            .set("wall_s", wall_s)
            .set("events_per_s", ev_s)
            .set("peak_mem_bytes", peak);
        stream_rows.push(row);
        stream_stats.push((arrivals, ev_s, peak));
    }

    // The constant-memory acceptance: every longer stream holds peak
    // memory within 1.5x of the shortest point, and per-event
    // throughput within 2x (streaming must not trade time for space).
    let (a0, sev0, peak0) = stream_stats[0];
    for &(a, sev, peak) in &stream_stats[1..] {
        assert!(
            peak as f64 <= 1.5 * peak0 as f64,
            "peak memory must stay flat as the stream grows: \
             {a0} arrivals -> {peak0} B, {a} arrivals -> {peak} B (> 1.5x)"
        );
        assert!(
            sev >= 0.5 * sev0,
            "streaming must hold per-event throughput within 2x: \
             {a0} arrivals -> {sev0:.0}/s, {a} arrivals -> {sev:.0}/s"
        );
    }

    let mut doc = Json::obj();
    doc.set("bench", "cluster_scale")
        .set("pattern", "poisson")
        .set("rps", RPS)
        .set("duration_s", DURATION_S)
        .set("policy", "jsq")
        .set("sample_d", SAMPLE_D)
        .set("max_n", max_n)
        .set("rows", Json::Arr(rows))
        .set("max_arrivals", max_arrivals)
        .set("stream_rows", Json::Arr(stream_rows))
        .set("bench_threads", auto);
    let out = std::env::var("CLUSTER_SCALE_JSON")
        .unwrap_or_else(|_| "BENCH_cluster_scale.json".into());
    std::fs::write(&out, doc.pretty()).expect("write bench json");
    println!("wrote {out}");
}
