//! Cluster scale bench: the PR 9 tentpole acceptance. Sweeps cluster
//! size N at a fixed offered load and times the full lockstep serve
//! (route + step + drain) under the indexed next-event stepper with
//! JSQ(d) snapshot sampling, against the retained linear oracle.
//!
//! The claim under test is *near-linear in events, not N×events*: at a
//! fixed arrival stream, growing the cluster from 8 to 1000 stacks must
//! not grow the per-event cost — the heap only steps stacks with work
//! due and the router only snapshots d sampled candidates, so idle
//! stacks are free. The linear oracle pays O(N) per arrival and is
//! timed alongside to show the gap. Asserts: event throughput at the
//! largest N within 2x of N=8 (indexed stepper); heap byte-identical to
//! the oracle at every N; output byte-identical across runs and thread
//! counts. Emits `BENCH_cluster_scale.json` (override the path via
//! `CLUSTER_SCALE_JSON`; cap the sweep via `CLUSTER_SCALE_MAX_N` — CI
//! smokes N ≤ 128; schema: DESIGN.md §Bench-Schemas).

use hetrax::cluster::Stepper;
use hetrax::config::Config;
use hetrax::decode::decodetest;
use hetrax::decode::DecodeConfig;
use hetrax::model::ModelId;
use hetrax::traffic::{ArrivalPattern, OutputLenDist, RequestMix, RoutePolicy};
use hetrax::util::bench::Bencher;
use hetrax::util::json::Json;
use hetrax::util::pool;

/// Fixed offered load: the datacenter regime (many mostly-idle stacks)
/// where indexed stepping pays off. Per-stack load falls as N grows.
const RPS: f64 = 2000.0;
const DURATION_S: f64 = 0.25;
const SAMPLE_D: usize = 4;

fn scenario(n: usize, stepper: Stepper) -> DecodeConfig {
    let mix = RequestMix::single(ModelId::BertBase)
        .with_output(OutputLenDist::Geometric { mean: 6.0 });
    let mut dc = DecodeConfig::new(ArrivalPattern::Poisson { rps: RPS }, mix);
    dc.duration_s = DURATION_S;
    dc.stacks = n;
    dc.policy = RoutePolicy::JoinShortestQueue;
    dc.seed = 0xCA1E;
    dc.threads = 1;
    dc.sample_d = SAMPLE_D;
    dc.stepper = stepper;
    dc
}

fn main() {
    let cfg = Config::default();
    let auto = pool::resolve_threads(0);
    let max_n: usize = std::env::var("CLUSTER_SCALE_MAX_N")
        .ok()
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(1000);

    let mut sizes: Vec<usize> =
        [8usize, 64, 256, 1000].into_iter().filter(|&n| n <= max_n).collect();
    if sizes.is_empty() {
        sizes.push(max_n.max(1));
    } else if *sizes.last().unwrap() < max_n {
        sizes.push(max_n);
    }

    let b = Bencher::quick();
    let mut rows: Vec<Json> = Vec::new();
    let mut events_per_s: Vec<(usize, f64)> = Vec::new();
    for &n in &sizes {
        let idx = scenario(n, Stepper::Indexed);
        let lin = scenario(n, Stepper::Linear);

        // The heap must be invisible in the output at every size.
        let report = decodetest::run(&cfg, &idx);
        let oracle = decodetest::run(&cfg, &lin);
        assert_eq!(
            report.to_json(&idx).pretty(),
            oracle.to_json(&lin).pretty(),
            "N={n}: indexed stepper diverged from the linear oracle"
        );
        let events = report.total.submitted;

        let t_idx = b.time(&format!("indexed  N={n:<5}"), || decodetest::run(&cfg, &idx));
        let t_lin = b.time(&format!("linear   N={n:<5}"), || decodetest::run(&cfg, &lin));
        let ev_s = events as f64 / t_idx.median_s();
        events_per_s.push((n, ev_s));

        let mut row = Json::obj();
        row.set("stacks", n)
            .set("rps", RPS)
            .set("events", events)
            .set("completed", report.total.completed)
            .set("indexed_median_s", t_idx.median_s())
            .set("linear_median_s", t_lin.median_s())
            .set("events_per_s", ev_s)
            .set("speedup_vs_linear", t_lin.median_s() / t_idx.median_s());
        rows.push(row);
    }

    // The tentpole acceptance: near-linear in events, not N×events —
    // per-event throughput at the largest N within 2x of the smallest.
    let (n0, ev0) = events_per_s[0];
    let (n1, ev1) = *events_per_s.last().unwrap();
    println!(
        "\n  event throughput: N={n0} -> {:.0} events/s, N={n1} -> {:.0} events/s ({:.2}x)",
        ev0,
        ev1,
        ev0 / ev1
    );
    if n1 > n0 {
        assert!(
            ev1 >= 0.5 * ev0,
            "indexed stepper must hold per-event throughput within 2x \
             from N={n0} ({ev0:.0}/s) to N={n1} ({ev1:.0}/s)"
        );
    }

    // Determinism contract: the document is byte-identical across
    // repeated runs and across thread counts at the largest size.
    let doc_of = |threads: usize| {
        let mut dc = scenario(n1, Stepper::Indexed);
        dc.threads = threads;
        decodetest::run(&cfg, &dc).to_json(&dc).pretty()
    };
    let canonical = doc_of(1);
    assert_eq!(canonical, doc_of(1), "same config+seed must reproduce byte-identically");
    assert_eq!(canonical, doc_of(auto), "thread count must not change the output");

    let mut doc = Json::obj();
    doc.set("bench", "cluster_scale")
        .set("pattern", "poisson")
        .set("rps", RPS)
        .set("duration_s", DURATION_S)
        .set("policy", "jsq")
        .set("sample_d", SAMPLE_D)
        .set("max_n", max_n)
        .set("rows", Json::Arr(rows))
        .set("bench_threads", auto);
    let out = std::env::var("CLUSTER_SCALE_JSON")
        .unwrap_or_else(|_| "BENCH_cluster_scale.json".into());
    std::fs::write(&out, doc.pretty()).expect("write bench json");
    println!("wrote {out}");
}
