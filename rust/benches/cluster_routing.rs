//! Cluster routing bench: the retired pre-pass KV-aware baseline vs
//! live routing through the cluster co-simulation core, on the shared
//! two-wave skewed replay mix
//! (`decodetest::cluster_routing_scenario`) — the scenario where the
//! pre-pass model's *estimated* releases and the stacks' *actual*
//! completions disagree, so reacting to live state is worth real p99
//! TTFT.
//!
//! Asserts the tentpole acceptance: live-kv or live-latency p99 TTFT ≤
//! pre-pass-kv at token parity, and byte-identical output across runs
//! and thread counts. Emits `BENCH_cluster.json` (path overridable via
//! `BENCH_CLUSTER_JSON`; schema: DESIGN.md §Bench-Schemas) for the
//! cluster-routing trajectory across commits.

use hetrax::config::Config;
use hetrax::decode::{decodetest, DecodeReport};
use hetrax::traffic::RoutePolicy;
use hetrax::util::bench::Bencher;
use hetrax::util::json::Json;
use hetrax::util::pool;

fn ttft_p99_ms(r: &DecodeReport) -> f64 {
    r.total.ttft_us.percentile(99.0) as f64 / 1e3
}

fn summary(r: &DecodeReport) -> Json {
    let mut j = Json::obj();
    j.set("completed", r.total.completed)
        .set("tokens", r.total.tokens_out)
        .set("ttft_p99_ms", ttft_p99_ms(r))
        .set("ttft_max_ms", r.total.ttft_us.max() as f64 / 1e3)
        .set("itl_p99_ms", r.total.itl_us.percentile(99.0) as f64 / 1e3)
        .set("makespan_s", r.total.makespan_s);
    j
}

fn main() {
    let cfg = Config::default();
    let auto = pool::resolve_threads(0);

    let b = Bencher::quick();
    let t_prepass = b.time("pre-pass-kv assignment + lockstep serve", || {
        decodetest::run_prepass_kv(
            &cfg,
            &decodetest::cluster_routing_scenario(&cfg, RoutePolicy::KvAware),
        )
    });
    let t_live = b.time("live-kv lockstep serve", || {
        decodetest::run(&cfg, &decodetest::cluster_routing_scenario(&cfg, RoutePolicy::KvAware))
    });

    let dc_kv = decodetest::cluster_routing_scenario(&cfg, RoutePolicy::KvAware);
    let prepass = decodetest::run_prepass_kv(&cfg, &dc_kv);
    let live_kv = decodetest::run(&cfg, &dc_kv);
    let dc_lat = decodetest::cluster_routing_scenario(&cfg, RoutePolicy::LatencyAware);
    let live_latency = decodetest::run(&cfg, &dc_lat);

    // Determinism contract: byte-identical JSON across repeated runs
    // and across thread counts (HETRAX_THREADS aside, the knob below is
    // the same lever).
    let again = decodetest::run(&cfg, &dc_kv);
    assert_eq!(
        live_kv.to_json(&dc_kv).pretty(),
        again.to_json(&dc_kv).pretty(),
        "same config+seed must reproduce byte-identically"
    );
    let mut dc_par = decodetest::cluster_routing_scenario(&cfg, RoutePolicy::KvAware);
    dc_par.threads = auto;
    let parallel = decodetest::run(&cfg, &dc_par);
    assert_eq!(
        live_kv.to_json(&dc_kv).pretty(),
        parallel.to_json(&dc_par).pretty(),
        "thread count must not change cluster output"
    );

    // Token parity: every mode serves the same stream to completion.
    assert_eq!(prepass.total.completed, live_kv.total.completed);
    assert_eq!(prepass.total.tokens_out, live_kv.total.tokens_out, "token parity");
    assert_eq!(prepass.total.tokens_out, live_latency.total.tokens_out, "token parity");

    // The acceptance: live routing wins or ties the pre-pass fiction.
    let best_live = ttft_p99_ms(&live_kv).min(ttft_p99_ms(&live_latency));
    assert!(
        best_live <= ttft_p99_ms(&prepass),
        "live routing (kv {:.3} ms / latency {:.3} ms) must win or tie pre-pass-kv {:.3} ms",
        ttft_p99_ms(&live_kv),
        ttft_p99_ms(&live_latency),
        ttft_p99_ms(&prepass)
    );

    println!(
        "\n  p99 TTFT: pre-pass-kv {:.3} ms | live-kv {:.3} ms | live-latency {:.3} ms",
        ttft_p99_ms(&prepass),
        ttft_p99_ms(&live_kv),
        ttft_p99_ms(&live_latency)
    );

    let mut doc = Json::obj();
    doc.set("bench", "cluster_routing")
        .set("stacks", dc_kv.stacks)
        .set("seed", dc_kv.seed)
        .set("requests", prepass.total.submitted)
        .set("prepass_kv", summary(&prepass))
        .set("live_kv", summary(&live_kv))
        .set("live_latency", summary(&live_latency))
        .set(
            "ttft_p99_improvement",
            ttft_p99_ms(&prepass) / best_live.max(1e-9),
        )
        .set("run_median_prepass_s", t_prepass.median_s())
        .set("run_median_live_s", t_live.median_s())
        .set("bench_threads", auto);
    let out =
        std::env::var("BENCH_CLUSTER_JSON").unwrap_or_else(|_| "BENCH_cluster.json".into());
    std::fs::write(&out, doc.pretty()).expect("write bench json");
    println!("wrote {out}");
}
