//! Serve loadtest smoke bench — a short seeded Poisson run through the
//! whole traffic subsystem (generate → route → admission-controlled
//! serve), timing the end-to-end wall clock and asserting the
//! byte-identical-output contract across thread counts. Emits
//! `BENCH_serve.json` (path overridable via `BENCH_SERVE_JSON`; schema:
//! DESIGN.md §Bench-Schemas) for the CI serve trajectory.
use hetrax::config::Config;
use hetrax::model::ModelId;
use hetrax::traffic::loadtest::{self, LoadtestConfig};
use hetrax::traffic::{ArrivalPattern, RequestMix, RoutePolicy};
use hetrax::util::bench::Bencher;
use hetrax::util::{mem, pool};

/// Report `peak_mem_bytes` from the counting allocator (util::mem);
/// the library never installs the shim on its own.
#[global_allocator]
static ALLOC: mem::CountingAlloc = mem::CountingAlloc;

fn config(threads: usize) -> LoadtestConfig {
    let mut lt = LoadtestConfig::new(
        ArrivalPattern::Poisson { rps: 300.0 },
        RequestMix::single(ModelId::BertBase),
    );
    lt.duration_s = 1.0;
    lt.stacks = 2;
    lt.policy = RoutePolicy::JoinShortestQueue;
    lt.seed = 7;
    lt.threads = threads;
    lt
}

fn main() {
    let cfg = Config::default();
    let auto = pool::resolve_threads(0);

    let b = Bencher::quick();
    let t_serial = b.time("poisson loadtest, 2 stacks (threads=1)", || {
        loadtest::run(&cfg, &config(1))
    });
    let t_par = b.time(
        &format!("poisson loadtest, 2 stacks (threads={auto})"),
        || loadtest::run(&cfg, &config(auto)),
    );

    // Determinism contract: identical JSON at any thread count.
    let lt = config(1);
    let serial = loadtest::run(&cfg, &lt).to_json(&lt).pretty();
    let lt_par = config(auto);
    let parallel = loadtest::run(&cfg, &lt_par).to_json(&lt_par).pretty();
    assert_eq!(serial, parallel, "loadtest output must not depend on threads");

    mem::reset_peak();
    let report = loadtest::run(&cfg, &lt);
    let peak_mem = mem::peak_bytes();
    println!(
        "\n  {} completed / {} submitted, p99 {:.2} ms, ReRAM peak {:.1} C, {} throttle events",
        report.total.completed,
        report.total.submitted,
        report.total.latency_us.percentile(99.0) as f64 / 1e3,
        report.reram_peak_c,
        report.throttle_events
    );

    let mut doc = report.to_json(&lt);
    doc.set("run_median_s", t_serial.median_s())
        .set("run_median_parallel_s", t_par.median_s())
        .set("peak_mem_bytes", peak_mem)
        .set("bench_threads", auto);
    let out = std::env::var("BENCH_SERVE_JSON").unwrap_or_else(|_| "BENCH_serve.json".into());
    std::fs::write(&out, doc.pretty()).expect("write bench json");
    println!("wrote {out}");
}
