//! Cluster fault-injection bench: the canonical failover scenario
//! (`decodetest::faulted_cluster_scenario`) — one stack crashed
//! mid-wave, one thermally quarantined — served through the seeded
//! fault layer on the cluster co-simulation core.
//!
//! Asserts the tentpole acceptance: exact request conservation with
//! retries double-entry accounted, ≥ 99% of retryable requests
//! completed despite the faults, byte-identical output across runs and
//! thread counts for the fixed fault seed, and an *empty*
//! `FaultSchedule` reproducing the plain cluster path bit-identically.
//! Emits `BENCH_faults.json` (path overridable via
//! `BENCH_FAULTS_JSON`; schema: DESIGN.md §Bench-Schemas) for the
//! failover trajectory across commits.

use hetrax::cluster::{FaultSchedule, HealthState};
use hetrax::config::Config;
use hetrax::decode::decodetest;
use hetrax::traffic::RoutePolicy;
use hetrax::util::bench::Bencher;
use hetrax::util::pool;

fn main() {
    let cfg = Config::default();
    let auto = pool::resolve_threads(0);

    let (dc, schedule) = decodetest::faulted_cluster_scenario(RoutePolicy::KvAware);

    let b = Bencher::quick();
    let t_faulted = b.time("faulted lockstep serve + failover", || {
        decodetest::run_with_faults(&cfg, &dc, &schedule)
    });

    let (report, outcome) = decodetest::run_with_faults(&cfg, &dc, &schedule);

    // Conservation: every delivery attempt and every surrendered request
    // is accounted — retries are double-entry (shed on the dying stack,
    // re-submitted on the failover target).
    let t = &report.total;
    assert!(
        outcome.conserved(t.submitted, t.completed, t.shed, t.refused_kv),
        "request conservation violated: {}",
        outcome.to_json().pretty()
    );

    // The faults actually fired: a crash and a thermal quarantine.
    assert_eq!(outcome.crashes, 1, "the scheduled crash must apply");
    assert_eq!(outcome.final_health[0], HealthState::Dead);
    assert!(outcome.thermal_trips >= 1, "the thermal rule must trip");
    assert!(outcome.surrendered > 0 && outcome.requeued > 0);

    // The acceptance: failover completes ≥ 99% of retryable requests.
    let rate = outcome.retryable_completion_rate(t.completed);
    assert!(
        rate >= 0.99,
        "failover must complete >= 99% of retryable requests (got {rate:.4})"
    );

    // Determinism contract: byte-identical across repeated runs and
    // across thread counts for the same fault seed.
    let doc_of = |threads: usize| {
        let mut dcx = dc.clone();
        dcx.threads = threads;
        let (r, o) = decodetest::run_with_faults(&cfg, &dcx, &schedule);
        format!("{}\n{}", r.to_json(&dcx).pretty(), o.to_json().pretty())
    };
    let canonical = doc_of(dc.threads);
    assert_eq!(canonical, doc_of(dc.threads), "same seed must reproduce byte-identically");
    assert_eq!(canonical, doc_of(auto), "thread count must not change faulted output");

    // Empty schedule ≡ the plain cluster path, bit for bit.
    let plain = decodetest::run(&cfg, &dc);
    let (unfaulted, o0) = decodetest::run_with_faults(&cfg, &dc, &FaultSchedule::empty());
    assert_eq!(
        plain.to_json(&dc).pretty(),
        unfaulted.to_json(&dc).pretty(),
        "empty FaultSchedule must be bit-identical to the plain cluster path"
    );
    assert_eq!(o0.requeued + o0.failed + o0.surrendered, 0);

    println!(
        "\n  failover: {} retryable, {} completed ({:.2}% within deadline), \
         {} requeued, {} failed",
        outcome.retryable(),
        t.completed,
        rate * 100.0,
        outcome.requeued,
        outcome.failed
    );

    let mut doc = report.to_json(&dc);
    doc.set("bench", "cluster_faults")
        .set("fault_schedule", schedule.to_json())
        // The windowed form adds per-stack health-transition counts and
        // the thermal-trip window indices (DESIGN.md §Bench-Schemas).
        .set("faults", outcome.to_json_with_windows(dc.throttle.interval_s))
        .set("retryable_completion_rate", rate)
        .set("run_median_faulted_s", t_faulted.median_s())
        .set("bench_threads", auto);
    let out = std::env::var("BENCH_FAULTS_JSON").unwrap_or_else(|_| "BENCH_faults.json".into());
    std::fs::write(&out, doc.pretty()).expect("write bench json");
    println!("wrote {out}");
}
