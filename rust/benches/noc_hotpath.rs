//! NoC simulator hot-path bench — the §Perf headline metric
//! (flit-hops/second) plus routing/evaluation microbenchmarks.
//!
//! Reports the fast-lane gain directly: "rebuild `NocSim` per run" is the
//! pre-fast-lane sweep shape, "reused instance" is the `reset()` lane
//! sweeps use now (DESIGN.md §Perf). Emits `BENCH_noc.json` (path
//! overridable via `BENCH_NOC_JSON`; schema: DESIGN.md §Bench-Schemas)
//! for the CI perf trajectory.
use hetrax::arch::Placement;
use hetrax::config::Config;
use hetrax::noc::{traffic, NocSim, Topology};
use hetrax::util::bench::Bencher;
use hetrax::util::json::Json;
use hetrax::util::rng::Rng;

fn main() {
    let cfg = Config::default();
    let p = Placement::mesh_baseline(&cfg);
    let topo = Topology::build(&cfg, &p);

    // Saturating uniform-random trace.
    let mut rng = Rng::new(1);
    let flows: Vec<traffic::Flow> = (0..200)
        .map(|i| traffic::Flow { src: i % 43, dst: (i * 11 + 5) % 43, bytes: 8192.0 })
        .filter(|f| f.src != f.dst)
        .collect();
    let trace = traffic::trace_from_flows(&cfg, &flows, 2_000, &mut rng);
    let total_flits: u64 = trace.packets.iter().map(|p| p.flits as u64).sum();

    let b = Bencher::default();
    let t_rebuild = b.time("cycle sim: rebuild NocSim per run", || {
        let mut sim = NocSim::new(&cfg, &topo);
        sim.run(&trace, 10_000_000)
    });
    let mut sim = NocSim::new(&cfg, &topo);
    let t_reuse = b.time("cycle sim: reused instance (reset fast lane)", || {
        sim.run(&trace, 10_000_000)
    });

    // Report the perf metric off the fast lane.
    let report = sim.run(&trace, 10_000_000);
    let hops_per_s = report.flit_hops as f64 / t_reuse.median_s();
    let reuse_speedup = t_rebuild.median_s() / t_reuse.median_s();
    println!("\n  flit-hops/s: {:.2} M  (cycles {} | flits {} | {:.3} flits/cycle)",
             hops_per_s / 1e6, report.cycles, total_flits, report.throughput());
    println!("  sweep speedup, reused instance vs rebuild-per-run: {reuse_speedup:.2}x");

    b.time("analytic Eq.1 utilization (200 flows)", || {
        topo.utilization_stats(&cfg, &flows, 1e-3)
    });
    b.time("routed path lookup (all pairs)", || {
        let mut acc = 0usize;
        for s in 0..topo.n {
            for d in 0..topo.n {
                acc += topo.path(s, d).map(|p| p.len()).unwrap_or(0);
            }
        }
        acc
    });

    // Machine-readable record for the CI perf trajectory.
    let mut doc = Json::obj();
    doc.set("bench", "noc_hotpath")
        .set("flit_hops_per_s", hops_per_s)
        .set("flit_hops", report.flit_hops)
        .set("cycles", report.cycles)
        .set("delivered_flits", report.delivered_flits)
        .set("throughput_flits_per_cycle", report.throughput())
        .set("run_median_s", t_reuse.median_s())
        .set("rebuild_median_s", t_rebuild.median_s())
        .set("reuse_speedup", reuse_speedup);
    let out = std::env::var("BENCH_NOC_JSON").unwrap_or_else(|_| "BENCH_noc.json".into());
    std::fs::write(&out, doc.pretty()).expect("write bench json");
    println!("wrote {out}");
}
