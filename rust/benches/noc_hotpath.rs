//! NoC simulator hot-path bench — the §Perf headline metric
//! (flit-hops/second) plus routing/evaluation microbenchmarks.
use hetrax::arch::Placement;
use hetrax::config::Config;
use hetrax::noc::{traffic, NocSim, Topology};
use hetrax::util::bench::Bencher;
use hetrax::util::rng::Rng;

fn main() {
    let cfg = Config::default();
    let p = Placement::mesh_baseline(&cfg);
    let topo = Topology::build(&cfg, &p);

    // Saturating uniform-random trace.
    let mut rng = Rng::new(1);
    let flows: Vec<traffic::Flow> = (0..200)
        .map(|i| traffic::Flow { src: i % 43, dst: (i * 11 + 5) % 43, bytes: 8192.0 })
        .filter(|f| f.src != f.dst)
        .collect();
    let trace = traffic::trace_from_flows(&cfg, &flows, 2_000, &mut rng);
    let total_flits: u64 = trace.packets.iter().map(|p| p.flits as u64).sum();

    let b = Bencher::default();
    let t = b.time("cycle sim: saturating trace to completion", || {
        let mut sim = NocSim::new(&cfg, &topo);
        sim.run(&trace, 10_000_000)
    });
    // Report the perf metric.
    let mut sim = NocSim::new(&cfg, &topo);
    let report = sim.run(&trace, 10_000_000);
    let hops_per_s = report.flit_hops as f64 / t.median_s();
    println!("\n  flit-hops/s: {:.2} M  (cycles {} | flits {} | {:.3} flits/cycle)",
             hops_per_s / 1e6, report.cycles, total_flits, report.throughput());

    b.time("analytic Eq.1 utilization (200 flows)", || {
        topo.utilization_stats(&cfg, &flows, 1e-3)
    });
    b.time("routed path lookup (all pairs)", || {
        let mut acc = 0usize;
        for s in 0..topo.n {
            for d in 0..topo.n {
                acc += topo.path(s, d).map(|p| p.len()).unwrap_or(0);
            }
        }
        acc
    });
}
