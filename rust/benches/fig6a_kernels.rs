//! Fig. 6a bench: per-kernel execution times vs baselines.
use hetrax::config::Config;
use hetrax::experiments::fig6a;
use hetrax::model::{ArchVariant, ModelId, Workload};
use hetrax::perf::PerfEstimator;
use hetrax::util::bench::Bencher;

fn main() {
    let cfg = Config::default();
    fig6a::run(&cfg, 1024);
    let w = Workload::build(ModelId::BertLarge, ArchVariant::EncoderOnly, 1024);
    let est = PerfEstimator::new(&cfg);
    let b = Bencher::default();
    println!();
    b.time("PerfEstimator::estimate (BERT-Large n=1024, 192 kernels)", || est.estimate(&w));
}
