//! Fig. 5 bench: router-port histogram + topology-construction timing.
use hetrax::arch::Placement;
use hetrax::config::Config;
use hetrax::experiments::common::Effort;
use hetrax::experiments::fig5;
use hetrax::noc::Topology;
use hetrax::util::bench::Bencher;

fn main() {
    let cfg = Config::default();
    let quick = std::env::var("HETRAX_FULL_BENCH").is_err();
    let effort = if quick { Effort::quick() } else { Effort::paper() };
    let outcome = fig5::run(&cfg, effort, 7);
    println!("\nmean ports: mesh {:.2} vs hetrax {:.2} | links {} vs {}",
             fig5::mean_ports(&outcome.mesh_hist),
             fig5::mean_ports(&outcome.hetrax_hist),
             outcome.mesh_links, outcome.hetrax_links);
    let p = Placement::mesh_baseline(&cfg);
    let b = Bencher::default();
    println!();
    b.time("Topology::build + routing tables (43 routers)", || Topology::build(&cfg, &p));
}
