//! Fig. 3 bench: regenerates the PT-vs-PTN placement figure and times the
//! DSE pipeline (placement evaluation is the MOO hot path).
use hetrax::arch::Placement;
use hetrax::config::Config;
use hetrax::experiments::common::Effort;
use hetrax::experiments::fig3;
use hetrax::optim::Evaluator;
use hetrax::util::bench::Bencher;

fn main() {
    let cfg = Config::default();
    let quick = std::env::var("HETRAX_FULL_BENCH").is_err();
    let effort = if quick { Effort::quick() } else { Effort::paper() };

    // The figure itself.
    let outcome = fig3::run(&cfg, effort, 42);
    println!("\nPT ReRAM tier {} vs PTN ReRAM tier {}",
             outcome.pt_reram_tier, outcome.ptn_reram_tier);

    // Hot-path timing: single-design objective evaluation.
    let w = hetrax::experiments::common::dse_workload();
    let ev = Evaluator::new(&cfg, &w);
    let p = Placement::mesh_baseline(&cfg);
    let b = Bencher::default();
    println!();
    b.time("objective evaluation (one design point)", || ev.evaluate(&p));
}
