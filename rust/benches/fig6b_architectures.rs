//! Fig. 6b bench: architecture variants, speedups and temperatures.
use hetrax::arch::Placement;
use hetrax::config::Config;
use hetrax::experiments::fig6b;
use hetrax::util::bench::Bencher;

fn main() {
    let cfg = Config::default();
    let mut p = Placement::mesh_baseline(&cfg);
    p.tier_order.swap(0, 3);
    fig6b::run(&cfg, 1024, &p);
    let b = Bencher::default();
    let w = hetrax::experiments::common::dse_workload();
    println!();
    b.time("hetrax_temp_c (estimate + power map + thermal solve)",
           || fig6b::hetrax_temp_c(&cfg, &p, &w));
}
