//! DSE wall-clock bench — MOO-STAGE end to end, serial (threads = 1, the
//! pre-parallel-engine path) vs the worker-pool fan-out, plus the seeded
//! determinism contract: both must produce byte-identical Pareto
//! archives. Emits `BENCH_dse.json` (path overridable via
//! `BENCH_DSE_JSON`; schema: DESIGN.md §Bench-Schemas) for the CI perf
//! trajectory.
use hetrax::config::Config;
use hetrax::model::{ArchVariant, ModelId, Workload};
use hetrax::optim::{DseResult, Evaluator, MooStage, ObjectiveSet};
use hetrax::util::bench::Bencher;
use hetrax::util::json::Json;
use hetrax::util::pool;
use hetrax::util::rng::Rng;

/// A fresh evaluator per run keeps the memo cold, so each timed sample
/// pays the same evaluation cost (memo hits *within* a run still count —
/// they are part of the engine being measured).
fn run_dse(cfg: &Config, w: &Workload, threads: usize, seed: u64) -> DseResult {
    let ev = Evaluator::new(cfg, w);
    let mut stage = MooStage::new(cfg, &ev, ObjectiveSet::ptn());
    stage.epochs = 6;
    stage.perturbations = 10;
    stage.steps_per_epoch = 6;
    stage.threads = threads;
    stage.run(&mut Rng::new(seed))
}

fn main() {
    let cfg = Config::default();
    let w = Workload::build(ModelId::BertLarge, ArchVariant::EncoderOnly, 512);
    let auto = pool::resolve_threads(0);

    let b = Bencher::quick();
    let t_serial = b.time("MOO-STAGE PTN, serial (threads=1)", || {
        run_dse(&cfg, &w, 1, 42)
    });
    let t_par = b.time(
        &format!("MOO-STAGE PTN, worker pool (threads={auto})"),
        || run_dse(&cfg, &w, auto, 42),
    );
    let speedup = t_serial.median_s() / t_par.median_s();

    // Determinism contract: identical archives regardless of threads.
    let serial = run_dse(&cfg, &w, 1, 7);
    let parallel = run_dse(&cfg, &w, auto, 7);
    assert_eq!(serial.evaluations, parallel.evaluations);
    assert_eq!(serial.history, parallel.history);
    assert_eq!(serial.archive.len(), parallel.archive.len());
    for (a, bb) in serial.archive.entries.iter().zip(&parallel.archive.entries) {
        assert_eq!(a.objectives.vals, bb.objectives.vals);
        assert!(a.placement == bb.placement);
    }
    println!("\n  determinism: serial and parallel archives identical \
              ({} entries, {} evaluations)",
             serial.archive.len(), serial.evaluations);
    println!("  DSE wall-clock speedup: {speedup:.2}x (threads={auto})");

    let mut doc = Json::obj();
    doc.set("bench", "dse_wallclock")
        .set("threads", auto)
        .set("serial_median_s", t_serial.median_s())
        .set("parallel_median_s", t_par.median_s())
        .set("speedup", speedup)
        .set("evaluations", serial.evaluations)
        .set("archive_len", serial.archive.len());
    let out = std::env::var("BENCH_DSE_JSON").unwrap_or_else(|_| "BENCH_dse.json".into());
    std::fs::write(&out, doc.pretty()).expect("write bench json");
    println!("wrote {out}");
}
