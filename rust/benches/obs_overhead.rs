//! Observability overhead bench: the zero-overhead-when-off contract,
//! measured. Times the `cluster_routing` scenario three ways — the
//! plain entry point, the explicit `Recorder::Off` path (the same code;
//! `decodetest::run` delegates), and a live recorder — and asserts the
//! off path costs < 2% wall-clock over the plain path (plus a small
//! absolute floor so timer noise on a millisecond-scale run cannot
//! flake the assertion). Also re-asserts the recorder's determinism
//! contract: a live recorder never perturbs the report, and the
//! exported trace and metrics are byte-identical across runs and
//! thread counts. Emits `BENCH_obs.json` (path overridable via
//! `BENCH_OBS_JSON`; schema: DESIGN.md §Bench-Schemas).

use hetrax::config::Config;
use hetrax::decode::decodetest;
use hetrax::obs::Recorder;
use hetrax::traffic::RoutePolicy;
use hetrax::util::bench::Bencher;
use hetrax::util::json::Json;
use hetrax::util::pool;

fn main() {
    let cfg = Config::default();
    let auto = pool::resolve_threads(0);
    let dc = decodetest::cluster_routing_scenario(&cfg, RoutePolicy::KvAware);

    let b = Bencher::quick();
    let t_base = b.time("cluster_routing, plain entry point", || {
        decodetest::run(&cfg, &dc)
    });
    let t_off = b.time("cluster_routing, Recorder::Off", || {
        decodetest::run_traced(&cfg, &dc, &Recorder::Off)
    });
    let t_on = b.time("cluster_routing, live recorder", || {
        decodetest::run_traced(&cfg, &dc, &Recorder::on())
    });

    // The headline assertion: recording disabled costs < 2% wall-clock.
    // The absolute floor (2 ms) keeps sub-millisecond timer jitter from
    // failing a contract that is structurally true (run == run_traced
    // with the off recorder, one enum discriminant branch per hook).
    let (base, off, on) = (t_base.median_s(), t_off.median_s(), t_on.median_s());
    assert!(
        off <= base * 1.02 + 0.002,
        "no-op recorder must cost < 2%: off {off:.6}s vs base {base:.6}s"
    );

    // A live recorder observes without perturbing.
    let plain = decodetest::run(&cfg, &dc);
    let rec = Recorder::on();
    let traced = decodetest::run_traced(&cfg, &dc, &rec);
    assert_eq!(
        plain.to_json(&dc).pretty(),
        traced.to_json(&dc).pretty(),
        "a live recorder must not change the report"
    );

    // Determinism: trace and metrics byte-identical across runs and
    // thread counts (all timestamps are virtual).
    let capture = |threads: usize| {
        let mut dcx = dc.clone();
        dcx.threads = threads;
        let r = Recorder::on();
        decodetest::run_traced(&cfg, &dcx, &r);
        (
            r.trace_json().expect("recorder on").pretty(),
            r.metrics_jsonl().expect("recorder on"),
        )
    };
    let (trace, metrics) = capture(dc.threads);
    assert_eq!((trace.clone(), metrics.clone()), capture(dc.threads), "reruns must match");
    assert_eq!((trace.clone(), metrics.clone()), capture(auto), "threads must not leak");

    let events = rec.with_buf(|buf| buf.events.len()).expect("recorder on");
    let overhead = |x: f64| if base > 0.0 { x / base - 1.0 } else { 0.0 };
    println!(
        "\n  overhead: off {:+.2}%, live {:+.2}% ({events} events recorded)",
        overhead(off) * 100.0,
        overhead(on) * 100.0
    );

    let mut doc = Json::obj();
    doc.set("bench", "obs_overhead")
        .set("scenario", "cluster_routing")
        .set("run_median_base_s", base)
        .set("run_median_off_s", off)
        .set("run_median_on_s", on)
        .set("off_overhead_frac", overhead(off))
        .set("on_overhead_frac", overhead(on))
        .set("trace_events", events)
        .set("metrics_lines", metrics.lines().count())
        .set("bench_threads", auto);
    let out = std::env::var("BENCH_OBS_JSON").unwrap_or_else(|_| "BENCH_obs.json".into());
    std::fs::write(&out, doc.pretty()).expect("write bench json");
    println!("wrote {out}");
}
