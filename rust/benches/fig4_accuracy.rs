//! Fig. 4 bench: accuracy-under-noise through the real PJRT classifier.
use hetrax::config::Config;
use hetrax::experiments::fig4;
use hetrax::reram::NoiseModel;
use hetrax::util::bench::Bencher;
use hetrax::util::rng::Rng;

fn main() {
    let cfg = Config::default();
    if std::path::Path::new("artifacts/manifest.json").exists() {
        fig4::run(&cfg, "artifacts", 78.0, 57.0, 7).expect("fig4");
    } else {
        println!("artifacts missing — run `make artifacts` for the full figure");
    }
    // Hot path: weight perturbation throughput.
    let noise = NoiseModel::new(&cfg, 78.0);
    let w: Vec<f32> = (0..65536).map(|i| ((i % 255) as f32 - 127.0) / 127.0).collect();
    let mut rng = Rng::new(5);
    let b = Bencher::default();
    println!();
    b.time("perturb_weights (64k weights)", || noise.perturb_weights(&w, &mut rng));
}
