//! Fig. 6c bench: the full EDP sweep (5 models × 4 sequence lengths).
use hetrax::config::Config;
use hetrax::experiments::fig6c;
use hetrax::util::bench::Bencher;

fn main() {
    let cfg = Config::default();
    let b = Bencher::quick();
    b.time("fig6c full sweep (20 design points × 3 accelerators)", || fig6c::run(&cfg));
}
