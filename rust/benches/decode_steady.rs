//! Decode steady-state smoke bench — a short seeded Poisson generation
//! run through the whole decode subsystem (generate → route →
//! continuous-batching serve with KV residency + thermal admission),
//! timing the end-to-end wall clock, asserting the byte-identical
//! contract across thread counts, and asserting the continuous-batching
//! throughput win over one-request-at-a-time serving on the same seeded
//! trace. Emits `BENCH_decode.json` (path overridable via
//! `BENCH_DECODE_JSON`; schema: DESIGN.md §Bench-Schemas) for the CI
//! decode trajectory.
use hetrax::config::Config;
use hetrax::decode::{decodetest, DecodeConfig};
use hetrax::model::ModelId;
use hetrax::traffic::{ArrivalPattern, OutputLenDist, RequestMix, RoutePolicy};
use hetrax::util::bench::Bencher;
use hetrax::util::pool;

fn config(threads: usize, max_running: usize) -> DecodeConfig {
    let mix = RequestMix::single(ModelId::BertBase)
        .with_output(OutputLenDist::Geometric { mean: 24.0 });
    // Overloads a one-at-a-time stack (~450 rps/stack offered) while a
    // continuous batch keeps up — the throughput-win assertion below
    // needs the serial baseline to saturate.
    let mut dc = DecodeConfig::new(ArrivalPattern::Poisson { rps: 900.0 }, mix);
    dc.duration_s = 0.6;
    dc.stacks = 2;
    dc.policy = RoutePolicy::JoinShortestQueue;
    dc.seed = 7;
    dc.threads = threads;
    dc.max_running = max_running;
    dc
}

fn main() {
    let cfg = Config::default();
    let auto = pool::resolve_threads(0);

    let b = Bencher::quick();
    let t_serial = b.time("decode run, 2 stacks (threads=1)", || {
        decodetest::run(&cfg, &config(1, 8))
    });
    let t_par = b.time(
        &format!("decode run, 2 stacks (threads={auto})"),
        || decodetest::run(&cfg, &config(auto, 8)),
    );

    // Determinism contract: identical JSON at any thread count.
    let dc = config(1, 8);
    let serial = decodetest::run(&cfg, &dc).to_json(&dc).pretty();
    let dc_par = config(auto, 8);
    let parallel = decodetest::run(&cfg, &dc_par).to_json(&dc_par).pretty();
    assert_eq!(serial, parallel, "decode output must not depend on threads");

    // Continuous batching must out-serve one-request-at-a-time on the
    // same seeded trace (the shared per-step weight streams).
    let report = decodetest::run(&cfg, &dc);
    let dc_one = config(1, 1);
    let one = decodetest::run(&cfg, &dc_one);
    assert!(
        report.tokens_per_s() > one.tokens_per_s(),
        "continuous {} tok/s vs one-at-a-time {} tok/s",
        report.tokens_per_s(),
        one.tokens_per_s()
    );

    println!(
        "\n  {} completed / {} submitted, {} tokens, ttft p99 {:.2} ms, itl p99 {:.3} ms, \
         kv peak {:.1} MiB, ReRAM peak {:.1} C",
        report.total.completed,
        report.total.submitted,
        report.total.tokens_out,
        report.total.ttft_us.percentile(99.0) as f64 / 1e3,
        report.total.itl_us.percentile(99.0) as f64 / 1e3,
        report.total.peak_kv_bytes / (1024.0 * 1024.0),
        report.reram_peak_c
    );
    println!(
        "  continuous batching speedup over one-at-a-time: {:.2}x tokens/s",
        report.tokens_per_s() / one.tokens_per_s().max(1e-9)
    );

    let mut doc = report.to_json(&dc);
    doc.set("run_median_s", t_serial.median_s())
        .set("run_median_parallel_s", t_par.median_s())
        .set("bench_threads", auto)
        .set("one_at_a_time_tokens_per_s", one.tokens_per_s())
        .set("continuous_tokens_per_s", report.tokens_per_s());
    let out = std::env::var("BENCH_DECODE_JSON").unwrap_or_else(|_| "BENCH_decode.json".into());
    std::fs::write(&out, doc.pretty()).expect("write bench json");
    println!("wrote {out}");
}
