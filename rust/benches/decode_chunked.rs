//! Chunked-prefill + KV-aware-routing smoke bench. Two seeded
//! comparisons through the full decode subsystem, both on the canonical
//! scenarios exported by `decode::decodetest` (the same ones the crate's
//! tests assert, so bench and tests can never drift apart):
//!
//! 1. **Chunking** — the long-prompt-heavy bursty trace served unchunked
//!    and with a 64-token prefill budget; asserts the tentpole
//!    acceptance (p99 ITL strictly lower at equal offered load, tokens
//!    within 5%) and the byte-identical contract across thread counts.
//! 2. **Routing** — the skewed two-class replay mix over two stacks
//!    under static `jsq` vs KV-occupancy-aware `kv-aware` routing.
//!
//! Emits `BENCH_chunked.json` (path overridable via `BENCH_CHUNKED_JSON`;
//! schema: DESIGN.md §Bench-Schemas) for the serving-QoS trajectory
//! across commits.

use hetrax::config::Config;
use hetrax::decode::{decodetest, DecodeReport};
use hetrax::traffic::RoutePolicy;
use hetrax::util::bench::Bencher;
use hetrax::util::json::Json;
use hetrax::util::pool;

fn itl_p99_ms(r: &DecodeReport) -> f64 {
    r.total.itl_us.percentile(99.0) as f64 / 1e3
}

fn summary(r: &DecodeReport) -> Json {
    let mut j = Json::obj();
    j.set("completed", r.total.completed)
        .set("tokens", r.total.tokens_out)
        .set("prefill_chunks", r.total.prefill_chunks)
        .set("itl_p99_ms", itl_p99_ms(r))
        .set("ttft_p99_ms", r.total.ttft_us.percentile(99.0) as f64 / 1e3)
        .set("makespan_s", r.total.makespan_s);
    j
}

fn main() {
    let cfg = Config::default();
    let auto = pool::resolve_threads(0);

    let b = Bencher::quick();
    let t_plain = b.time("decode run, unchunked (threads=1)", || {
        decodetest::run(&cfg, &decodetest::chunked_itl_scenario(0, 1))
    });
    let t_chunked = b.time("decode run, 64-token chunks (threads=1)", || {
        decodetest::run(&cfg, &decodetest::chunked_itl_scenario(64, 1))
    });

    // One report per config (runs are byte-identical by the determinism
    // contract, so the timed runs above need no separate re-runs).
    let dc = decodetest::chunked_itl_scenario(64, 1);
    let chunked = decodetest::run(&cfg, &dc);
    let plain = decodetest::run(&cfg, &decodetest::chunked_itl_scenario(0, 1));

    // Determinism contract: identical JSON at any thread count, with
    // chunking enabled.
    let dc_par = decodetest::chunked_itl_scenario(64, auto);
    let parallel = decodetest::run(&cfg, &dc_par);
    assert_eq!(
        chunked.to_json(&dc).pretty(),
        parallel.to_json(&dc_par).pretty(),
        "chunked output must not depend on threads"
    );

    // Tentpole acceptance: chunking strictly bounds p99 ITL at equal
    // offered load, within 5% of the unchunked token volume.
    assert!(chunked.total.prefill_chunks > 0, "the 512-token prompts must chunk");
    assert!(
        itl_p99_ms(&chunked) < itl_p99_ms(&plain),
        "chunked p99 ITL {:.3} ms must beat unchunked {:.3} ms",
        itl_p99_ms(&chunked),
        itl_p99_ms(&plain)
    );
    let (a, b_tok) = (chunked.total.tokens_out as f64, plain.total.tokens_out as f64);
    assert!(
        (a - b_tok).abs() <= 0.05 * b_tok.max(1.0),
        "chunked tokens {a} vs unchunked {b_tok} drifted past 5%"
    );

    // Routing comparison on the skewed mix.
    let jsq = decodetest::run(
        &cfg,
        &decodetest::skewed_routing_scenario(RoutePolicy::JoinShortestQueue),
    );
    let aware =
        decodetest::run(&cfg, &decodetest::skewed_routing_scenario(RoutePolicy::KvAware));
    assert_eq!(jsq.total.completed, aware.total.completed, "both serve the mix");
    assert!(
        aware.total.ttft_us.percentile(99.0) < jsq.total.ttft_us.percentile(99.0),
        "kv-aware p99 TTFT must beat jsq on the skewed mix"
    );

    println!(
        "\n  unchunked: itl p99 {:.3} ms | chunked: itl p99 {:.3} ms ({} chunks)",
        itl_p99_ms(&plain),
        itl_p99_ms(&chunked),
        chunked.total.prefill_chunks
    );
    println!(
        "  routing ttft p99: jsq {:.2} ms vs kv-aware {:.2} ms",
        jsq.total.ttft_us.percentile(99.0) as f64 / 1e3,
        aware.total.ttft_us.percentile(99.0) as f64 / 1e3
    );

    let mut routing = Json::obj();
    routing
        .set("jsq", summary(&jsq))
        .set("kv_aware", summary(&aware));
    let mut doc = Json::obj();
    doc.set("bench", "decode_chunked")
        .set("chunk_tokens", dc.chunk_tokens)
        .set("rps", dc.pattern.nominal_rps())
        .set("duration_s", dc.duration_s)
        .set("seed", dc.seed)
        .set("unchunked", summary(&plain))
        .set("chunked", summary(&chunked))
        .set(
            "itl_p99_improvement",
            itl_p99_ms(&plain) / itl_p99_ms(&chunked).max(1e-9),
        )
        .set("routing", routing)
        .set("run_median_s", t_plain.median_s())
        .set("run_median_chunked_s", t_chunked.median_s())
        .set("bench_threads", auto);
    let out =
        std::env::var("BENCH_CHUNKED_JSON").unwrap_or_else(|_| "BENCH_chunked.json".into());
    std::fs::write(&out, doc.pretty()).expect("write bench json");
    println!("wrote {out}");
}
