//! Fleet serving bench: the same prefill-heavy replay trace served
//! monolithically (every stack prefills and decodes) vs disaggregated
//! (prefill-specialized stacks hand their KV to decode stacks over the
//! interposer, the transfer charged as virtual-time delay).
//!
//! Asserts the tentpole acceptance: disaggregation beats the monolithic
//! fleet on p99 TTFT at exact token parity, zero-cost transfer with a
//! single decode stack pins completions against the monolithic path,
//! byte-identical output across runs and thread counts, and a
//! heterogeneous (mixed-arch) fleet that serves deterministically with
//! every conservation identity exact. Emits `BENCH_fleet.json` (path
//! overridable via `BENCH_FLEET_JSON`; schema: DESIGN.md
//! §Bench-Schemas) for the disaggregation trajectory across commits.

use hetrax::config::Config;
use hetrax::decode::{decodetest, DecodeConfig};
use hetrax::fleet::{self, FleetConfig, StackArchId};
use hetrax::model::ModelId;
use hetrax::traffic::{ArrivalPattern, ReplayEvent, RequestMix, RoutePolicy};
use hetrax::util::bench::Bencher;
use hetrax::util::pool;

/// Prefill-heavy open-loop trace: long prompts at 1 ms spacing, so the
/// offered work is dominated by 512-token prefills — the regime
/// prefill/decode disaggregation targets.
fn trace(n: usize) -> Vec<ReplayEvent> {
    (0..n)
        .map(|i| ReplayEvent {
            t_s: i as f64 * 0.001,
            model: ModelId::BertBase,
            variant: ModelId::BertBase.default_variant(),
            seq: 512,
            out_tokens: 32,
        })
        .collect()
}

/// Both fleets replay the identical trace with admission control off
/// and a queue-wait bound far beyond any plausible makespan: every
/// arrival is served, so the mono/disagg comparison is pure scheduling
/// (and token parity is exact, not modulo shed requests).
fn decode_config(stacks: usize, events: &[ReplayEvent]) -> DecodeConfig {
    let mix = RequestMix::single(ModelId::BertBase);
    let mut dc = DecodeConfig::new(
        ArrivalPattern::Replay { events: events.to_vec() },
        mix,
    );
    dc.stacks = stacks;
    dc.policy = RoutePolicy::KvAware;
    dc.max_running = 8;
    dc.threads = 1;
    dc.kv.capacity_bytes = 1024.0 * 1024.0 * 1024.0;
    dc.throttle.enabled = false;
    dc.throttle.max_queue_wait_s = 60.0;
    dc
}

fn fleet_config(dc: DecodeConfig, prefill_stacks: usize) -> FleetConfig {
    FleetConfig {
        dc,
        prefill_stacks,
        transfer_bw_bps: None,
        crash: None,
    }
}

fn main() {
    let cfg = Config::default();
    let auto = pool::resolve_threads(0);
    let events = trace(48);

    // Monolithic fleet: 4 hetrax3d stacks, each serving prefill + decode.
    let mono_dc = decode_config(4, &events);
    let b = Bencher::quick();
    let t_mono = b.time("monolithic 4-stack lockstep serve", || {
        decodetest::run(&cfg, &mono_dc)
    });
    let mono = decodetest::run(&cfg, &mono_dc);

    // Disaggregated fleet over the same trace: 3 prefill + 1 decode,
    // KV handed off at the modeled interposer bandwidth.
    let fc = fleet_config(decode_config(4, &events), 3);
    let t_disagg = b.time("disaggregated 3+1 serve + KV hand-off", || {
        fleet::run_disaggregated(&cfg, &fc)
    });
    let (report, out) = fleet::run_disaggregated(&cfg, &fc);
    let t = &report.total;

    assert!(
        out.conserved(t.submitted, t.completed, t.shed, t.refused_kv),
        "fleet conservation violated: {}",
        out.to_json().pretty()
    );
    assert!(out.delivered > 0, "the trace must exercise KV hand-offs");
    assert!(
        out.transferred_kv_bytes > 0.0 && out.transfer_s_total > 0.0,
        "finite interposer bandwidth must charge wire time"
    );

    // Token parity: a hand-off moves a request's remaining budget, it
    // never mints or drops tokens.
    assert_eq!(
        mono.total.tokens_out, t.tokens_out,
        "mono and disaggregated fleets must emit identical token counts"
    );
    assert_eq!(
        out.completed_logical(t.completed),
        mono.total.completed,
        "every request must complete end-to-end in both fleets"
    );

    // The acceptance: dedicating stacks to prefill turns slot turnover
    // from full-request service time into prefill time, so tail TTFT
    // drops even though one decode stack absorbs the whole decode load.
    let mono_ttft_p99 = mono.total.ttft_us.percentile(99.0);
    let disagg_ttft_p99 = t.ttft_us.percentile(99.0);
    assert!(
        disagg_ttft_p99 < mono_ttft_p99,
        "disaggregation must beat the monolithic fleet on p99 TTFT \
         (disagg {disagg_ttft_p99} us vs mono {mono_ttft_p99} us)"
    );

    // Zero-cost transfer + a single decode stack pins the disaggregated
    // path against the monolithic one at token parity.
    let zfc = FleetConfig {
        dc: decode_config(2, &events),
        prefill_stacks: 1,
        transfer_bw_bps: Some(f64::INFINITY),
        crash: None,
    };
    let (zr, zo) = fleet::run_disaggregated(&cfg, &zfc);
    let zmono = decodetest::run(&cfg, &zfc.dc);
    assert_eq!(zr.total.tokens_out, zmono.total.tokens_out);
    assert_eq!(zo.completed_logical(zr.total.completed), zmono.total.completed);
    assert_eq!(zo.transfer_s_total, 0.0, "infinite bandwidth is free");

    // Determinism contract: byte-identical across repeated runs and
    // across thread counts (phase-table precompute is the only
    // parallel section; serving is serial lockstep).
    let doc_of = |base: &FleetConfig, threads: usize| {
        let mut dcx = base.dc.clone();
        dcx.threads = threads;
        let fcx = FleetConfig {
            dc: dcx,
            prefill_stacks: base.prefill_stacks,
            transfer_bw_bps: base.transfer_bw_bps,
            crash: base.crash,
        };
        let (r, o) = fleet::run_disaggregated(&cfg, &fcx);
        format!("{}\n{}", r.to_json(&fcx.dc).pretty(), o.to_json().pretty())
    };
    let canonical = doc_of(&fc, 1);
    assert_eq!(canonical, doc_of(&fc, 1), "same trace must reproduce byte-identically");
    assert_eq!(canonical, doc_of(&fc, auto), "thread count must not change fleet output");

    // Heterogeneous fleet: chiplet prefill tier feeding a hetrax3d +
    // atleus-edge decode pair — conserved and deterministic.
    let mut het_dc = decode_config(4, &events);
    het_dc.archs = vec![
        StackArchId::Chiplet2p5d,
        StackArchId::Chiplet2p5d,
        StackArchId::Hetrax3d,
        StackArchId::AtleusEdge,
    ];
    let hfc = fleet_config(het_dc, 2);
    let (hr, ho) = fleet::run_disaggregated(&cfg, &hfc);
    let ht = &hr.total;
    assert!(
        ho.conserved(ht.submitted, ht.completed, ht.shed, ht.refused_kv),
        "heterogeneous fleet conservation violated"
    );
    assert!(ho.delivered > 0);
    assert_eq!(doc_of(&hfc, 1), doc_of(&hfc, auto), "mixed archs stay deterministic");

    println!(
        "\n  ttft p99: mono {:.2} ms vs disagg {:.2} ms ({} hand-offs, {:.2} MiB KV on the wire)",
        mono_ttft_p99 as f64 / 1e3,
        disagg_ttft_p99 as f64 / 1e3,
        out.delivered,
        out.transferred_kv_bytes / (1024.0 * 1024.0)
    );

    let mut doc = report.to_json(&fc.dc);
    doc.set("bench", "fleet_serving")
        .set("fleet", out.to_json())
        .set(
            "per_arch",
            fleet::per_arch_json(&hr, &fleet::resolve_archs(&hfc.dc.archs, hfc.dc.stacks)),
        )
        .set("mono_ttft_p99_us", mono_ttft_p99)
        .set("disagg_ttft_p99_us", disagg_ttft_p99)
        .set("mono_itl_p99_us", mono.total.itl_us.percentile(99.0))
        .set("disagg_itl_p99_us", t.itl_us.percentile(99.0))
        .set("mono_tokens_per_s", mono.tokens_per_s())
        .set("disagg_tokens_per_s", report.tokens_per_s())
        .set("run_median_mono_s", t_mono.median_s())
        .set("run_median_disagg_s", t_disagg.median_s())
        .set("bench_threads", auto);
    let out_path =
        std::env::var("BENCH_FLEET_JSON").unwrap_or_else(|_| "BENCH_fleet.json".into());
    std::fs::write(&out_path, doc.pretty()).expect("write bench json");
    println!("wrote {out_path}");
}
