//! Thermal design-space sweep: how peak and ReRAM-tier temperature move
//! with (a) the vertical position of the ReRAM tier, (b) ambient
//! temperature, and (c) workload intensity — the §4.3/§5.2 trade-off
//! surface behind Fig. 3, plus the resulting Fig. 4-style accuracy-risk
//! classification per operating point.
//!
//! Run with: `cargo run --release --example thermal_sweep`

use hetrax::arch::{Placement, TierKind};
use hetrax::config::Config;
use hetrax::model::{ArchVariant, ModelId, Workload};
use hetrax::perf::PerfEstimator;
use hetrax::power;
use hetrax::reram::NoiseModel;
use hetrax::thermal::{PowerGrid, ThermalModel};
use hetrax::util::bench::Table;

fn placement_with_reram_at(cfg: &Config, tier: usize) -> Placement {
    let mut p = Placement::mesh_baseline(cfg);
    let cur = p.reram_tier();
    p.tier_order.swap(cur, tier);
    let _ = TierKind::ReRam;
    p
}

fn main() {
    let cfg = Config::default();
    let w = Workload::build(ModelId::BertLarge, ArchVariant::EncoderOnly, 1024);
    let report = PerfEstimator::new(&cfg).estimate(&w);
    let powers = power::core_powers(&cfg, &report.activity);

    // --- (a) ReRAM tier position.
    let mut t1 = Table::new(
        "ReRAM tier position vs temperatures (BERT-Large n=1024)",
        &["peak °C", "ReRAM °C", "P(digit err)", "accuracy risk"],
    );
    for tier in 0..4 {
        let p = placement_with_reram_at(&cfg, tier);
        let grid = PowerGrid::from_core_powers(&cfg, &p, &powers);
        let th = ThermalModel::new(&cfg).evaluate(&grid);
        let reram_c = th.tier_peak_c[p.reram_tier()];
        let perr = NoiseModel::new(&cfg, reram_c).digit_error_probability();
        t1.row(
            &format!("tier {tier} {}", if tier == 0 { "(sink)" } else { "" }),
            &[
                format!("{:.1}", th.peak_c),
                format!("{reram_c:.1}"),
                format!("{perr:.2e}"),
                (if perr > 1e-3 { "LOSS" } else { "safe" }).to_string(),
            ],
        );
    }
    t1.print();

    // --- (b) Ambient sweep at the PTN stack.
    let mut t2 = Table::new("ambient temperature sweep (ReRAM at sink)", &[
        "peak °C", "ReRAM °C", "P(digit err)",
    ]);
    for ambient in [25.0, 35.0, 45.0, 55.0, 65.0] {
        let mut c = cfg.clone();
        c.ambient_c = ambient;
        let p = placement_with_reram_at(&c, 0);
        let grid = PowerGrid::from_core_powers(&c, &p, &powers);
        let th = ThermalModel::new(&c).evaluate(&grid);
        let reram_c = th.tier_peak_c[p.reram_tier()];
        let perr = NoiseModel::new(&c, reram_c).digit_error_probability();
        t2.row(&format!("{ambient:.0} °C"), &[
            format!("{:.1}", th.peak_c),
            format!("{reram_c:.1}"),
            format!("{perr:.2e}"),
        ]);
    }
    t2.print();

    // --- (c) Workload intensity (sequence length) sweep.
    let mut t3 = Table::new("workload sweep (PTN stack)", &["latency ms", "peak °C", "ReRAM °C"]);
    for seq in [128usize, 512, 1024, 2056] {
        let w = Workload::build(ModelId::BertLarge, ArchVariant::EncoderOnly, seq);
        let r = PerfEstimator::new(&cfg).estimate(&w);
        let p = placement_with_reram_at(&cfg, 0);
        let grid = PowerGrid::from_core_powers(&cfg, &p, &power::core_powers(&cfg, &r.activity));
        let th = ThermalModel::new(&cfg).evaluate(&grid);
        t3.row(&format!("n={seq}"), &[
            format!("{:.2}", r.latency_s * 1e3),
            format!("{:.1}", th.peak_c),
            format!("{:.1}", th.tier_peak_c[p.reram_tier()]),
        ]);
    }
    t3.print();
}
