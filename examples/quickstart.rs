//! Quickstart — the 60-second tour:
//!   1. load an AOT-compiled attention artifact and execute it via PJRT
//!      (real numerics, Python not involved),
//!   2. estimate a BERT-Large inference on the HeTraX architecture,
//!   3. run the thermal model on the resulting power map.
//!
//! Run with: `make artifacts && cargo run --release --example quickstart`

use anyhow::Result;

use hetrax::arch::Placement;
use hetrax::config::Config;
use hetrax::model::{ArchVariant, ModelId, Workload};
use hetrax::perf::PerfEstimator;
use hetrax::power;
use hetrax::runtime::Runtime;
use hetrax::thermal::{PowerGrid, ThermalModel};
use hetrax::util::rng::Rng;

fn main() -> Result<()> {
    let cfg = Config::default();

    // --- 1. Real numerics through the PJRT runtime.
    println!("== 1. AOT artifact execution (fused online-softmax attention) ==");
    match Runtime::open("artifacts") {
        Ok(mut rt) => {
            let platform = rt.platform();
            let art = rt.load("attention_tiny")?;
            let n: usize = art.inputs[0].element_count();
            let mut rng = Rng::new(0);
            let gen = |rng: &mut Rng| (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect::<Vec<_>>();
            let out = art.run_f32(&[gen(&mut rng), gen(&mut rng), gen(&mut rng)])?;
            println!("  platform: {platform}");
            println!("  attention({:?}) -> {} values, first = {:.6}",
                     art.inputs[0].shape, out[0].len(), out[0][0]);
        }
        Err(e) => println!("  (skipped — {e:#}; run `make artifacts`)"),
    }

    // --- 2. Architecture-level inference estimate.
    println!("\n== 2. HeTraX inference estimate (BERT-Large, n=1024) ==");
    let w = Workload::build(ModelId::BertLarge, ArchVariant::EncoderOnly, 1024);
    let report = PerfEstimator::new(&cfg).estimate(&w);
    println!("  latency: {:.2} ms | energy: {:.2} J | EDP: {:.4} J·s",
             report.latency_s * 1e3, report.energy.total_j(), report.edp());
    for (kernel, t) in &report.kernel_time_s {
        println!("    {kernel:<6} {:.3} ms", t * 1e3);
    }

    // --- 3. Thermal feasibility.
    println!("\n== 3. Steady-state thermal map (PTN-style stack) ==");
    let mut placement = Placement::mesh_baseline(&cfg);
    placement.tier_order.swap(0, 3); // ReRAM nearest the sink (Fig. 3b)
    let powers = power::core_powers(&cfg, &report.activity);
    let grid = PowerGrid::from_core_powers(&cfg, &placement, &powers);
    let thermal = ThermalModel::new(&cfg).evaluate(&grid);
    for (t, peak) in thermal.tier_peak_c.iter().enumerate() {
        let kind = if t == placement.reram_tier() { "ReRAM" } else { "SM-MC" };
        println!("  tier {t} ({kind:<5}): peak {:.1} °C", peak);
    }
    println!("  system peak {:.1} °C (DRAM limit 95 °C — feasible: {})",
             thermal.peak_c, thermal.peak_c < 95.0);
    Ok(())
}
