//! End-to-end serving driver (DESIGN.md §End-to-end validation): load the
//! real bert-tiny weights, serve batched inference requests through the
//! coordinator, execute the *actual* transformer numerics layer-by-layer
//! on the PJRT runtime, and report latency/throughput — proving all three
//! layers compose: Pallas kernels (inside the HLO) → JAX model (the AOT
//! artifact) → Rust coordinator (batching, tier pipeline, timing/energy).
//!
//! Run with: `make artifacts && cargo run --release --example bert_inference`

use std::time::Instant;

use anyhow::{anyhow, Result};

use hetrax::config::Config;
use hetrax::coordinator::{Batcher, BatcherConfig, Engine, Request};
use hetrax::model::ModelId;
use hetrax::runtime::Runtime;
use hetrax::util::json::Json;
use hetrax::util::rng::Rng;
use hetrax::util::tensor_io::Archive;

const NUM_REQUESTS: usize = 32;

fn main() -> Result<()> {
    let cfg = Config::default();
    let mut rt = Runtime::open("artifacts")
        .map_err(|e| anyhow!("{e:#}\nrun `make artifacts` first"))?;
    let weights = Archive::load("artifacts/bert_tiny_weights.htx")?;
    let manifest = rt.manifest().clone();
    let layers = manifest.at(&["bert_tiny", "layers"]).unwrap().as_usize().unwrap();
    let seq = manifest.at(&["bert_tiny", "seq"]).unwrap().as_usize().unwrap();
    let d = manifest.at(&["bert_tiny", "d_model"]).unwrap().as_usize().unwrap();
    let names: Vec<String> = manifest
        .at(&["bert_tiny", "param_names"])
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|j| Json::as_str(j).unwrap().to_string())
        .collect();

    // Per-layer parameter buffers in artifact order.
    let mut layer_params: Vec<Vec<Vec<f32>>> = Vec::with_capacity(layers);
    for l in 0..layers {
        let mut params = Vec::with_capacity(names.len());
        for n in &names {
            params.push(
                weights
                    .get(&format!("l{l}_{n}"))
                    .ok_or_else(|| anyhow!("missing l{l}_{n}"))?
                    .as_f32()?,
            );
        }
        layer_params.push(params);
    }

    println!("bert-tiny serving: {layers} layers, seq {seq}, d_model {d}");
    println!("compiling encoder-block executable ...");
    let t0 = Instant::now();
    rt.load("encoder_block_tiny")?;
    println!("  compiled in {:.2?}", t0.elapsed());

    // Build a batch of real requests with embedded inputs.
    let mut rng = Rng::new(123);
    let requests: Vec<Request> = (0..NUM_REQUESTS as u64)
        .map(|i| {
            let mut r = Request::synthetic(i, ModelId::BertTiny, seq, i as f64 * 1e-4);
            r.input = Some((0..seq * d).map(|_| rng.normal(0.0, 1.0) as f32).collect());
            r
        })
        .collect();
    let batches = Batcher::new(BatcherConfig { max_batch: 8, max_wait_s: 1e-3 })
        .form_batches(requests);
    println!("serving {NUM_REQUESTS} requests in {} batches ...", batches.len());

    let engine = Engine::new(&cfg);
    let wall = Instant::now();
    let mut all_outputs = 0usize;
    let mut sim_makespan: f64 = 0.0;
    let mut total_energy = 0.0;
    let mut latencies: Vec<f64> = Vec::new();
    for batch in &batches {
        let report = engine.serve_with_numerics(
            &mut rt, "encoder_block_tiny", batch, &layer_params)?;
        for resp in &report.responses {
            let out = resp.output.as_ref().expect("numerics attached");
            assert_eq!(out.len(), seq * d);
            assert!(out.iter().all(|v| v.is_finite()));
            all_outputs += 1;
            latencies.push(resp.latency_s);
        }
        sim_makespan = sim_makespan.max(report.makespan_s);
        total_energy += report.total_energy_j;
    }
    let wall_elapsed = wall.elapsed();

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let avg = latencies.iter().sum::<f64>() / latencies.len() as f64;
    println!("\n== results ==");
    println!("  completed:          {all_outputs}/{NUM_REQUESTS} with real numerics");
    println!("  wall-clock:         {wall_elapsed:.2?} ({:.1} req/s host throughput)",
             NUM_REQUESTS as f64 / wall_elapsed.as_secs_f64());
    println!("  simulated makespan: {:.3} ms on HeTraX ({:.0} req/s device throughput)",
             sim_makespan * 1e3, NUM_REQUESTS as f64 / sim_makespan);
    println!("  simulated latency:  avg {:.3} ms | p99 {:.3} ms",
             avg * 1e3, latencies[latencies.len() - 1] * 1e3);
    println!("  simulated energy:   {:.2} mJ total ({:.3} mJ/req)",
             total_energy * 1e3, total_energy * 1e3 / NUM_REQUESTS as f64);
    println!("\nrecorded in EXPERIMENTS.md §End-to-end.");
    Ok(())
}
