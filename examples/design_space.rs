//! Design-space exploration ablation (§4.4 / DESIGN.md ablation index):
//! MOO-STAGE vs AMOSA vs random search at an equal evaluation budget on
//! the Eq. 6 PTN problem — the comparison the paper cites MOO-STAGE [10]
//! winning, especially at high objective counts.
//!
//! Run with: `cargo run --release --example design_space [-- full]`

use hetrax::config::Config;
use hetrax::experiments::common;
use hetrax::optim::amosa::Amosa;
use hetrax::optim::random_search::RandomSearch;
use hetrax::optim::{Evaluator, MooStage, ObjectiveSet};
use hetrax::util::bench::Table;
use hetrax::util::rng::Rng;

fn front_quality(archive: &hetrax::optim::ParetoArchive) -> (f64, usize) {
    // Balanced scalarized best + front size (simple, monotone proxies
    // for front quality; lower scalar is better).
    let best = archive.best_scalarized().expect("front non-empty");
    let scale = [1.0, 1.0, 2000.0, 0.25];
    let q: f64 = (0..4)
        .filter(|&i| archive.set.active[i])
        .map(|i| best.objectives.vals[i] / scale[i])
        .sum::<f64>()
        / archive.set.count() as f64;
    (q, archive.len())
}

fn main() {
    let full = std::env::args().any(|a| a == "full");
    let cfg = Config::default();
    let w = common::dse_workload();
    let ev = Evaluator::new(&cfg, &w);
    let set = ObjectiveSet::ptn();

    let (epochs, steps, perturb) = if full { (50, 10, 10) } else { (12, 6, 8) };
    let budget = epochs * steps * perturb;
    println!("PTN design-space ablation, budget ≈ {budget} evaluations each\n");

    let mut table = Table::new(
        "optimizer ablation (lower best-scalar = better)",
        &["best scalar", "front size", "evaluations"],
    );

    let mut stage = MooStage::new(&cfg, &ev, set);
    stage.epochs = epochs;
    stage.steps_per_epoch = steps;
    stage.perturbations = perturb;
    let stage_res = stage.run(&mut Rng::new(7));
    let (q, n) = front_quality(&stage_res.archive);
    table.row("MOO-STAGE", &[format!("{q:.4}"), n.to_string(),
                             stage_res.evaluations.to_string()]);

    let amosa = Amosa {
        evaluator: &ev,
        set,
        iterations: budget,
        t_start: 1.0,
        t_end: 1e-3,
        speculation: 8,
        threads: 0,
    };
    let amosa_res = amosa.run(&mut Rng::new(7));
    let (q, n) = front_quality(&amosa_res.archive);
    table.row("AMOSA", &[format!("{q:.4}"), n.to_string(),
                         amosa_res.evaluations.to_string()]);

    let random = RandomSearch { evaluator: &ev, set, samples: budget, threads: 0 };
    let random_res = random.run(&mut Rng::new(7));
    let (q, n) = front_quality(&random_res.archive);
    table.row("random", &[format!("{q:.4}"), n.to_string(),
                          random_res.evaluations.to_string()]);

    table.print();

    println!("\nMOO-STAGE convergence (best scalar per epoch):");
    for (i, q) in stage_res.history.iter().enumerate() {
        println!("  epoch {i:>3}: {q:.4}");
    }
}
