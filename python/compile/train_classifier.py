"""Build-time training of the Fig. 4 classifier on the synthetic GLUE
stand-ins (DESIGN.md substitution table).

Training runs through a pure-jnp twin of the classifier forward pass
(identical math, no Pallas, no quantization) for speed and differentiability;
the trained weights are then *deployed* through the kernel-based forward
(crossbar-quantized FF) exactly as the Rust Fig. 4 driver does. Hand-rolled
Adam — no optimizer library on the image.

Outputs (``artifacts/``):
  classifier_{task}.htx       — trained weights (PARAM_NAMES order)
  eval_{task}.htx             — held-out eval set (x: f32, y: i32)
"""

from __future__ import annotations

import functools
import math
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import classifier as clf
from . import model as model_lib
from . import tensor_io

TRAIN_N = 2048
EVAL_N = 512
BATCH = 128
STEPS = 400
LR = 3e-3


def _attention_ref(q, k, v):
    d = q.shape[-1]
    s = jnp.einsum("hqd,hkd->hqk", q, k) / math.sqrt(d)
    return jnp.einsum("hqk,hkd->hqd", jax.nn.softmax(s, axis=-1), v)


def _layernorm_ref(x, g, b, eps=1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * g + b


def forward_ref(x_emb, params):
    """Differentiable twin of classifier.forward_single (pure jnp)."""
    cfg = clf.CLF_CONFIG
    n_block = len(model_lib.BLOCK_PARAM_NAMES)
    x = x_emb + model_lib.positional_encoding(clf.SEQ_LEN, clf.D_MODEL)
    for i in range(clf.LAYERS):
        wq, wk, wv, wo, g1, b1, wf1, wf2, g2, b2 = params[i * n_block:(i + 1) * n_block]
        q = model_lib._split_heads(x @ wq, cfg.heads)
        k = model_lib._split_heads(x @ wk, cfg.heads)
        v = model_lib._split_heads(x @ wv, cfg.heads)
        h = model_lib._merge_heads(_attention_ref(q, k, v)) @ wo
        m = _layernorm_ref(x + h, g1, b1)
        x1 = jax.nn.gelu(m @ wf1, approximate=True)
        x2 = jax.nn.gelu(x1 @ wf2, approximate=True)
        x = _layernorm_ref(m + x2, g2, b2)
    head_w, head_b = params[clf.LAYERS * n_block], params[clf.LAYERS * n_block + 1]
    return jnp.mean(x, axis=0) @ head_w + head_b


def loss_fn(params, xb, yb):
    logits = jax.vmap(lambda x: forward_ref(x, params))(xb)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], axis=1))


@functools.partial(jax.jit, static_argnums=())
def adam_step(params, m, v, t, xb, yb):
    """One Adam step (β1=0.9, β2=0.999, eps=1e-8)."""
    grads = jax.grad(loss_fn)(params, xb, yb)
    b1, b2, eps = 0.9, 0.999, 1e-8
    new_params, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = b1 * mi + (1 - b1) * g
        vi = b2 * vi + (1 - b2) * jnp.square(g)
        mhat = mi / (1 - b1 ** t)
        vhat = vi / (1 - b2 ** t)
        new_params.append(p - LR * mhat / (jnp.sqrt(vhat) + eps))
        new_m.append(mi)
        new_v.append(vi)
    return new_params, new_m, new_v


def accuracy_ref(params, x, y, batch=256):
    correct = 0
    for i in range(0, x.shape[0], batch):
        logits = jax.vmap(lambda xx: forward_ref(xx, params))(x[i:i + batch])
        correct += int(jnp.sum(jnp.argmax(logits, -1) == y[i:i + batch]))
    return correct / x.shape[0]


def train_task(task_name: str, seed: int = 0, steps: int = STEPS,
               verbose: bool = True):
    task = clf.TASKS[task_name]
    key = jax.random.PRNGKey(seed)
    kd, ke, ki = jax.random.split(key, 3)
    x_train, y_train = clf.make_dataset(task, kd, TRAIN_N)
    x_eval, y_eval = clf.make_dataset(task, ke, EVAL_N)
    params = clf.init_params(ki)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]

    rng = np.random.default_rng(seed)
    for t in range(1, steps + 1):
        idx = rng.integers(0, TRAIN_N, BATCH)
        params, m, v = adam_step(params, m, v, t,
                                 x_train[idx], y_train[idx])
        if verbose and t % 100 == 0:
            acc = accuracy_ref(params, x_eval, y_eval)
            print(f"  [{task_name}] step {t:4d} eval acc {acc:.4f}")
    acc = accuracy_ref(params, x_eval, y_eval)
    if verbose:
        print(f"  [{task_name}] final ref-forward eval acc {acc:.4f}")
    return params, (x_eval, y_eval), acc


def export_task(task_name: str, out_dir: str, seed: int = 0,
                steps: int = STEPS) -> float:
    params, (x_eval, y_eval), acc = train_task(task_name, seed, steps)
    weights = {name: np.asarray(p) for name, p in zip(clf.PARAM_NAMES, params)}
    tensor_io.write_archive(
        os.path.join(out_dir, f"classifier_{task_name}.htx"), weights)
    tensor_io.write_archive(
        os.path.join(out_dir, f"eval_{task_name}.htx"),
        {"x": np.asarray(x_eval, np.float32),
         "y": np.asarray(y_eval, np.int32)})
    return acc


if __name__ == "__main__":
    os.makedirs("../artifacts", exist_ok=True)
    for t in ("sst2-syn", "qnli-syn"):
        export_task(t, "../artifacts")
