"""Sequence classifier used for the Fig. 4 accuracy-vs-ReRAM-noise study.

A small transformer encoder (2 blocks) + mean-pool + linear head, trained
at build time by :mod:`compile.train_classifier` on the two synthetic GLUE
stand-ins described in DESIGN.md (SST2-syn, QNLI-syn). The forward pass is
AOT-lowered with *weights as HLO parameters*, so the Rust side (Fig. 4
driver) can inject temperature-dependent ReRAM conductance perturbations
into the FF weights and measure the resulting accuracy.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import model as model_lib
from .kernels import primitives as prim_k

# Classifier geometry — small enough to train in seconds on CPU while
# keeping real multi-block attention + crossbar-mapped FF layers.
SEQ_LEN = 32
D_MODEL = 32
HEADS = 2
D_FF = 128
LAYERS = 2
NUM_CLASSES = 2

CLF_CONFIG = model_lib.ModelConfig("clf-tiny", LAYERS, D_MODEL, HEADS, D_FF)

# Flat parameter order (the AOT manifest and Rust reader rely on it):
# per-layer block params then the head.
PARAM_NAMES = tuple(
    f"l{i}_{n}" for i in range(LAYERS) for n in model_lib.BLOCK_PARAM_NAMES
) + ("head_w", "head_b")


def param_shapes() -> dict[str, tuple[int, ...]]:
    shapes = {}
    block = model_lib.block_param_shapes(CLF_CONFIG)
    for i in range(LAYERS):
        for n, s in block.items():
            shapes[f"l{i}_{n}"] = s
    shapes["head_w"] = (D_MODEL, NUM_CLASSES)
    shapes["head_b"] = (NUM_CLASSES,)
    return shapes


def init_params(key: jax.Array) -> list[jax.Array]:
    params = []
    for i in range(LAYERS):
        key, sub = jax.random.split(key)
        params.extend(model_lib.init_block_params(sub, CLF_CONFIG))
    key, sub = jax.random.split(key)
    params.append(jax.random.normal(sub, (D_MODEL, NUM_CLASSES)) * 0.1)
    params.append(jnp.zeros((NUM_CLASSES,)))
    return params


def forward_single(x_emb: jax.Array, params, *, on_reram: bool = True,
                   interpret: bool = True) -> jax.Array:
    """Logits for one embedded sequence (SEQ_LEN, D_MODEL) → (NUM_CLASSES,)."""
    n_block = len(model_lib.BLOCK_PARAM_NAMES)
    layer_params = [params[i * n_block:(i + 1) * n_block] for i in range(LAYERS)]
    head_w, head_b = params[LAYERS * n_block], params[LAYERS * n_block + 1]
    h = model_lib.encoder(x_emb, layer_params, CLF_CONFIG,
                          on_reram=on_reram, interpret=interpret)
    pooled = jnp.mean(h, axis=0)
    return pooled @ head_w + head_b


def forward_batch(x_batch: jax.Array, params, *, on_reram: bool = True,
                  interpret: bool = True) -> jax.Array:
    """Logits for a batch (B, SEQ_LEN, D_MODEL) → (B, NUM_CLASSES).

    Uses lax.map (sequential over examples) rather than vmap so the lowered
    HLO stays a compact while-loop — this is the artifact Rust executes.
    """
    def one(x):
        return forward_single(x, params, on_reram=on_reram, interpret=interpret)
    return jax.lax.map(one, x_batch)


def predict(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1)


def softmax_probs(logits: jax.Array) -> jax.Array:
    return prim_k.softmax(logits)


@dataclasses.dataclass(frozen=True)
class SynTask:
    """A synthetic binary classification task over embedded sequences.

    * ``sst2-syn`` (sentiment stand-in): a class-dependent "cue" vector is
      added at a few random token positions; the model must attend to the
      sparse cues to classify. Mirrors sentiment cues in a sentence.
    * ``qnli-syn`` (entailment stand-in): the sequence is two halves; label
      1 iff both halves share a common latent direction. The model must
      compare segments — a cross-segment attention task.
    """
    name: str
    noise_scale: float = 1.0


def make_dataset(task: SynTask, key: jax.Array, n: int):
    """Returns (x: (n, SEQ_LEN, D_MODEL) f32, y: (n,) int32)."""
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    y = jax.random.bernoulli(k1, 0.5, (n,)).astype(jnp.int32)
    base = task.noise_scale * jax.random.normal(k2, (n, SEQ_LEN, D_MODEL))
    if task.name == "sst2-syn":
        # Fixed (per-task) cue directions for the two classes.
        cue_pos = jax.random.normal(jax.random.PRNGKey(101), (D_MODEL,))
        cue_neg = jax.random.normal(jax.random.PRNGKey(102), (D_MODEL,))
        cue = jnp.where(y[:, None] == 1, cue_pos[None], cue_neg[None])
        # 3 random cue positions per example; per-example cue strength varies
        # so examples near the decision boundary exist (noise sensitivity).
        pos = jax.random.randint(k3, (n, 3), 0, SEQ_LEN)
        onehot = jax.nn.one_hot(pos, SEQ_LEN).sum(axis=1)  # (n, SEQ_LEN)
        strength = 0.25 + 0.75 * jax.random.uniform(k5, (n, 1, 1))
        x = base + strength * onehot[:, :, None] * cue[:, None, :]
        return x.astype(jnp.float32), y
    if task.name == "qnli-syn":
        half = SEQ_LEN // 2
        latent = jax.random.normal(k3, (n, D_MODEL))
        other = jax.random.normal(k4, (n, D_MODEL))
        # Premise half always carries `latent`; hypothesis half carries the
        # same latent iff y == 1, an unrelated latent otherwise.
        hyp = jnp.where(y[:, None] == 1, latent, other)
        # Per-example signal strength varies so borderline examples exist.
        strength = 1.0 + 0.8 * jax.random.uniform(k5, (n, 1, 1))
        x = base
        x = x.at[:, :half, :].add(strength * latent[:, None, :])
        x = x.at[:, half:, :].add(strength * hyp[:, None, :])
        return x.astype(jnp.float32), y
    raise ValueError(f"unknown task {task.name}")


TASKS = {
    "sst2-syn": SynTask("sst2-syn"),
    "qnli-syn": SynTask("qnli-syn"),
}
