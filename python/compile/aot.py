"""AOT compiler: lower the L2 model to HLO *text* artifacts for the Rust
runtime, train + export the Fig. 4 classifier, and write the artifact
manifest.

Interchange format is HLO text, NOT ``.serialize()``: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``). The text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run from ``python/``:  ``python -m compile.aot --out-dir ../artifacts``
(this is what ``make artifacts`` does). Python never runs at request time;
the Rust binary is self-contained once ``artifacts/`` exists.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import classifier as clf
from . import model as model_lib
from . import tensor_io
from . import train_classifier
from .kernels import attention as attn_k

# Dimensions of the artifacts the Rust examples/tests execute. bert-tiny is
# the real published BERT-Tiny geometry; SEQ is kept at 128 so the
# interpret-mode pallas loops stay fast on CPU.
TINY = model_lib.MODEL_ZOO["bert-tiny"]
TINY_SEQ = 128
ATTN_HEADS, ATTN_SEQ, ATTN_HEAD_DIM = 2, 128, 64
CLF_BATCH = 64


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_attention():
    """Standalone fused-attention artifact (quickstart + runtime tests)."""
    def fn(q, k, v):
        return (attn_k.fused_attention(q, k, v),)

    s = _spec((ATTN_HEADS, ATTN_SEQ, ATTN_HEAD_DIM))
    lowered = jax.jit(fn).lower(s, s, s)
    inputs = [("q", s.shape), ("k", s.shape), ("v", s.shape)]
    return to_hlo_text(lowered), inputs, [("out", s.shape)]


def lower_encoder_block(variant: str = "encoder_only"):
    """One Table-1 block, weights as HLO parameters (bert-tiny dims)."""
    cfg = model_lib.ModelConfig("bert-tiny", 2, TINY.d_model, TINY.heads,
                                TINY.d_ff, variant)
    shapes = model_lib.block_param_shapes(cfg)

    def fn(x, *params):
        causal = variant == "decoder_only"
        return (model_lib.encoder_block(x, params, cfg, causal=causal),)

    x_spec = _spec((TINY_SEQ, cfg.d_model))
    param_specs = [_spec(shapes[n]) for n in model_lib.BLOCK_PARAM_NAMES]
    lowered = jax.jit(fn).lower(x_spec, *param_specs)
    inputs = [("x", x_spec.shape)] + [
        (n, shapes[n]) for n in model_lib.BLOCK_PARAM_NAMES]
    return to_hlo_text(lowered), inputs, [("out", x_spec.shape)]


def lower_classifier():
    """Batched classifier forward, weights as parameters (Fig. 4 driver)."""
    shapes = clf.param_shapes()

    def fn(x_batch, *params):
        return (clf.forward_batch(x_batch, list(params)),)

    x_spec = _spec((CLF_BATCH, clf.SEQ_LEN, clf.D_MODEL))
    param_specs = [_spec(shapes[n]) for n in clf.PARAM_NAMES]
    lowered = jax.jit(fn).lower(x_spec, *param_specs)
    inputs = [("x", x_spec.shape)] + [(n, shapes[n]) for n in clf.PARAM_NAMES]
    return to_hlo_text(lowered), inputs, [("logits", (CLF_BATCH, clf.NUM_CLASSES))]


def export_bert_tiny_weights(out_dir: str) -> None:
    """Random-init bert-tiny weights for the end-to-end serving example
    (the example measures systems behaviour, not task accuracy)."""
    key = jax.random.PRNGKey(42)
    tensors: dict[str, np.ndarray] = {}
    for layer in range(TINY.layers):
        key, sub = jax.random.split(key)
        params = model_lib.init_block_params(sub, TINY)
        for name, p in zip(model_lib.BLOCK_PARAM_NAMES, params):
            tensors[f"l{layer}_{name}"] = np.asarray(p)
    tensor_io.write_archive(os.path.join(out_dir, "bert_tiny_weights.htx"),
                            tensors)


def export_golden_archive(out_dir: str) -> None:
    """Golden HTX file cross-checking the Python writer vs the Rust reader."""
    tensor_io.write_archive(
        os.path.join(out_dir, "golden.htx"),
        {
            "f32_2x3": np.arange(6, dtype=np.float32).reshape(2, 3) / 4.0,
            "i32_4": np.array([-2, -1, 0, 2_000_000_000], np.int32),
            "u8_scalar": np.array(255, np.uint8),
            "f32_empty": np.zeros((0, 5), np.float32),
        })


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--skip-train", action="store_true",
                    help="skip classifier training (artifacts for tests only)")
    ap.add_argument("--train-steps", type=int, default=train_classifier.STEPS)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest: dict = {"format": "hlo-text", "artifacts": {}}

    jobs = {
        "attention_tiny": lower_attention,
        "encoder_block_tiny": lambda: lower_encoder_block("encoder_only"),
        "encoder_block_tiny_mqa": lambda: lower_encoder_block("mqa"),
        "encoder_block_tiny_parallel": lambda: lower_encoder_block("parallel"),
        "decoder_block_tiny": lambda: lower_encoder_block("decoder_only"),
        "classifier": lower_classifier,
    }
    for name, job in jobs.items():
        text, inputs, outputs = job()
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [{"name": n, "shape": list(s)} for n, s in inputs],
            "outputs": [{"name": n, "shape": list(s)} for n, s in outputs],
        }
        print(f"wrote {path} ({len(text)} chars, {len(inputs)} inputs)")

    export_bert_tiny_weights(args.out_dir)
    export_golden_archive(args.out_dir)

    accs = {}
    if not args.skip_train:
        for t in ("sst2-syn", "qnli-syn"):
            print(f"training classifier on {t} ...")
            accs[t] = train_classifier.export_task(t, args.out_dir,
                                                   steps=args.train_steps)
    manifest["classifier"] = {
        "batch": CLF_BATCH, "seq": clf.SEQ_LEN, "d_model": clf.D_MODEL,
        "param_names": list(clf.PARAM_NAMES),
        "ref_eval_acc": accs,
    }
    manifest["bert_tiny"] = {
        "layers": TINY.layers, "d_model": TINY.d_model, "heads": TINY.heads,
        "d_ff": TINY.d_ff, "seq": TINY_SEQ,
        "param_names": list(model_lib.BLOCK_PARAM_NAMES),
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
