"""Layer-1 Pallas kernels for HeTraX.

Two kernels carry the paper's kernel-level ideas:

* :mod:`attention` -- the fused score + online-softmax attention executed on
  the SM-MC tiers (paper section 4.2 "MHA"), expressed as a Pallas kernel with
  the flash-attention schedule (Q blocks resident, K/V streamed, running
  max/sum carries; the score matrix S never materializes in HBM).

* :mod:`crossbar` -- the ReRAM-crossbar matrix multiplication executed on the
  PIM tier (paper section 4.2 "FF"), expressed as a bit-sliced integer matmul
  with DAC/ADC quantization and additive thermal conductance noise (Eq. 5).

All kernels run under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls) and are validated against the pure-jnp oracles in :mod:`ref`
by the pytest suite.
"""
