"""ReRAM crossbar matrix multiplication (paper §4.1/§4.2, "FF").

Functional model of the PIM tier: a 128×128 1T1R crossbar array with
2-bit/cell conductance storage (Table 2), 1-bit DACs on the rows and 8-bit
ADCs on the columns. An 8-bit weight is bit-sliced across ``8/2 = 4``
adjacent cells; an 8-bit activation is applied over 8 one-bit DAC cycles.
The analog dot product along a column accumulates the per-slice partial
sums, each clipped by the ADC range, and the digital shift-add reassembles
the full-precision product.

Thermal conductance noise (paper Eq. 5) enters as additive Gaussian noise
on the stored conductances::

    Noise(λ) = N(0, sqrt(4 · G · K_b · T_ReRAM · F) / V)

The noise standard deviation is computed from the tier temperature by
``conductance_noise_sigma`` below (same formula as the Rust side,
``rust/src/reram/noise.rs``; the two are cross-checked by tests).

The Pallas kernel performs the quantize → sliced-integer-matmul →
ADC-clip → rescale pipeline, tiled to the crossbar geometry. The analog
physics is simulated digitally; the *dataflow* (weight-stationary,
activations streaming, per-column ADC saturation) matches the paper.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Table 2 geometry.
CROSSBAR_ROWS = 128
CROSSBAR_COLS = 128
CELL_BITS = 2
WEIGHT_BITS = 8
ADC_BITS = 8
NUM_SLICES = WEIGHT_BITS // CELL_BITS  # 4 cells per 8-bit weight

# Physical constants for Eq. 5.
BOLTZMANN = 1.380649e-23          # J/K
RERAM_G_ON = 1.0 / 25e3           # S  (25 kΩ LRS, ISAAC-class device)
RERAM_FREQ = 10e6                 # Hz (Table 2: 10 MHz)
RERAM_READ_V = 0.2                # V  read voltage


def conductance_noise_sigma(temp_kelvin: float, *, g: float = RERAM_G_ON,
                            f: float = RERAM_FREQ, v: float = RERAM_READ_V) -> float:
    """σ of the thermal (Johnson–Nyquist) conductance noise, Eq. 5.

    Returned in units of conductance (S); divide by ``g`` for the relative
    perturbation applied to a normalized weight.
    """
    return math.sqrt(4.0 * g * BOLTZMANN * temp_kelvin * f) / v


def relative_noise_sigma(temp_kelvin: float) -> float:
    """Eq. 5 noise relative to the on-conductance — the σ applied to
    normalized (|w| ≤ 1) weight values."""
    return conductance_noise_sigma(temp_kelvin) / RERAM_G_ON


def quantize_weights(w: jax.Array, bits: int = WEIGHT_BITS):
    """Symmetric per-tensor quantization to ``bits`` signed levels.

    Returns (w_q int32 in [-(2^(b-1)-1), 2^(b-1)-1], scale f32).
    """
    qmax = float(2 ** (bits - 1) - 1)
    scale = jnp.maximum(jnp.max(jnp.abs(w)), 1e-12) / qmax
    w_q = jnp.clip(jnp.round(w / scale), -qmax, qmax).astype(jnp.int32)
    return w_q, scale


def slice_weights(w_q: jax.Array):
    """Bit-slice signed int8-range weights into NUM_SLICES × 2-bit planes.

    Uses offset-binary: w_off = w_q + 128 ∈ [0, 255] is split into base-4
    digits; the offset is subtracted digitally after the analog MACs (the
    standard ISAAC/NeuroSim trick to store signed weights in unipolar
    conductances).

    Returns (slices, offset) where slices has shape (NUM_SLICES,) + w.shape
    holding digits in [0, 3], most significant slice first.
    """
    w_off = (w_q + 2 ** (WEIGHT_BITS - 1)).astype(jnp.int32)
    digits = []
    for i in range(NUM_SLICES - 1, -1, -1):
        digits.append((w_off // (4 ** i)) % 4)
    return jnp.stack(digits, axis=0), 2 ** (WEIGHT_BITS - 1)


def _crossbar_kernel(x_ref, wslice_ref, noise_ref, o_ref, *,
                     adc_max: int, rows_per_xbar: int, n_slices: int):
    """One (row-tile, col-tile) program of the sliced analog MVM.

    x_ref:      (m, kb)            int32 activations (already quantized)
    wslice_ref: (n_slices, kb, nb) int32 digit planes in [0,3]
    noise_ref:  (n_slices, kb, nb) f32 conductance noise (normalized units)
    o_ref:      (m, nb)            f32 accumulated partial output

    Grid is (n_tiles, k_tiles) with the K axis innermost so the same output
    block is revisited on consecutive programs; it is zeroed on the first
    K step and accumulated afterwards.
    """
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    kb = x_ref.shape[1]
    # Each group of `rows_per_xbar` input rows shares one physical crossbar;
    # the ADC clips the *per-crossbar* column sum. kb is a multiple of
    # rows_per_xbar by construction (padding in the wrapper).
    n_xbars = kb // rows_per_xbar

    total = jnp.zeros((x.shape[0], o_ref.shape[1]), jnp.float32)
    for s in range(n_slices):
        w = wslice_ref[s].astype(jnp.float32) + noise_ref[s]
        # Analog MAC per crossbar segment with ADC saturation.
        xs = x.reshape(x.shape[0], n_xbars, rows_per_xbar)
        ws = w.reshape(n_xbars, rows_per_xbar, w.shape[1])
        # partial[m, b, n] = Σ_r xs[m,b,r] · ws[b,r,n]
        partial = jax.lax.dot_general(
            xs, ws, (((2,), (1,)), ((1,), (0,))),
            preferred_element_type=jnp.float32)
        # dot_general with batch dims returns (b, m, n).
        partial = jnp.clip(partial, -adc_max, adc_max)
        col = jnp.sum(partial, axis=0)  # digital accumulation across crossbars
        total = total + col * float(4 ** (n_slices - 1 - s))
    o_ref[...] = o_ref[...] + total


def crossbar_matmul(x: jax.Array, w: jax.Array, *,
                    temp_kelvin: float = 300.0,
                    noise_key: jax.Array | None = None,
                    adc_bits: int = ADC_BITS,
                    act_bits: int = 8,
                    tile_k: int = CROSSBAR_ROWS,
                    tile_n: int = CROSSBAR_COLS,
                    interpret: bool = True) -> jax.Array:
    """x @ w computed through the simulated ReRAM crossbar pipeline.

    Args:
      x: (m, k) f32 activations.
      w: (k, n) f32 stationary weights (mapped once to crossbars).
      temp_kelvin: ReRAM tier temperature — sets the Eq. 5 noise σ.
      noise_key: PRNG key for the conductance noise draw; None → noiseless
        (σ is still temperature-derived but a zero sample is used).
    Returns:
      (m, n) f32 ≈ x @ w (exact up to quantization + ADC clipping + noise).
    """
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"shape mismatch {x.shape} @ {w.shape}")

    # Quantize activations (DAC side) and weights (cells).
    x_q, x_scale = quantize_weights(x, act_bits)
    w_q, w_scale = quantize_weights(w, WEIGHT_BITS)
    slices, w_offset = slice_weights(w_q)          # (S, k, n) in [0,3]

    # Conductance noise: one draw per cell, σ from Eq. 5, in *digit* units
    # (a digit step of 1 corresponds to one conductance level out of 4).
    sigma_rel = relative_noise_sigma(temp_kelvin)
    sigma_digit = sigma_rel * (2 ** CELL_BITS - 1)
    if noise_key is not None and sigma_digit > 0:
        noise = sigma_digit * jax.random.normal(
            noise_key, (NUM_SLICES, k, n), jnp.float32)
    else:
        noise = jnp.zeros((NUM_SLICES, k, n), jnp.float32)

    # Pad K and N to crossbar multiples.
    pad_k = (-k) % tile_k
    pad_n = (-n) % tile_n
    kp, np_ = k + pad_k, n + pad_n
    x_q = jnp.pad(x_q, ((0, 0), (0, pad_k)))
    slices = jnp.pad(slices, ((0, 0), (0, pad_k), (0, pad_n)))
    noise = jnp.pad(noise, ((0, 0), (0, pad_k), (0, pad_n)))

    # ADC full-scale: with 1-bit DAC cycles the per-cycle column sum is at
    # most rows·3; an 8-bit ADC covers 255 levels. We model act-parallel
    # (not bit-serial) MACs, so scale the clip level by the activation
    # magnitude bound to keep the same *relative* saturation point.
    act_max = float(2 ** (act_bits - 1) - 1)
    adc_max = (2 ** adc_bits - 1) * act_max

    kernel = functools.partial(
        _crossbar_kernel, adc_max=adc_max, rows_per_xbar=tile_k,
        n_slices=NUM_SLICES)

    grid = (np_ // tile_n, kp // tile_k)  # K innermost → sequential revisits
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, tile_k), lambda nn, kk: (0, kk)),
            pl.BlockSpec((NUM_SLICES, tile_k, tile_n), lambda nn, kk: (0, kk, nn)),
            pl.BlockSpec((NUM_SLICES, tile_k, tile_n), lambda nn, kk: (0, kk, nn)),
        ],
        out_specs=pl.BlockSpec((m, tile_n), lambda nn, kk: (0, nn)),
        out_shape=jax.ShapeDtypeStruct((m, np_), jnp.float32),
        interpret=interpret,
    )(x_q, slices, noise)

    out = out[:, :n]
    # Undo the offset-binary: Σ_k x_k·(w_off − 128) = Σ x·w_off − 128·Σ x.
    x_row_sum = jnp.sum(x_q[:, :k].astype(jnp.float32), axis=1, keepdims=True)
    out = out - float(w_offset) * x_row_sum
    return out * (x_scale * w_scale)


def crossbars_required(k: int, n: int, *, rows: int = CROSSBAR_ROWS,
                       cols: int = CROSSBAR_COLS,
                       slices: int = NUM_SLICES) -> int:
    """Number of physical 128×128 crossbars to hold a (k, n) weight matrix.

    Matches the Rust-side mapping in ``rust/src/reram/mapping.rs`` (cross-
    checked by a test fixture in artifacts/).
    """
    k_tiles = -(-k // rows)
    n_tiles = -(-n // cols)
    return k_tiles * n_tiles * slices
