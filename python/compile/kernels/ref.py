"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package is validated against these references by
``python/tests/`` (hypothesis sweeps shapes/dtypes + assert_allclose).
The references are deliberately naive — materialize the score matrix, use
straightforward math — so a disagreement always indicts the kernel, not
the oracle.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = False, sm_scale: float | None = None) -> jax.Array:
    """Naive softmax(QKᵀ/√d)V per head. q,k,v: (heads, seq, head_dim)."""
    h, s, d = q.shape
    if k.shape[0] == 1 and h > 1:  # MQA broadcast
        k = jnp.broadcast_to(k, (h,) + k.shape[1:])
        v = jnp.broadcast_to(v, (h,) + v.shape[1:])
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    scores = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * sm_scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32)).astype(q.dtype)


def crossbar_ref(x: jax.Array, w: jax.Array, *, act_bits: int = 8,
                 weight_bits: int = 8) -> jax.Array:
    """Quantized matmul oracle: what the crossbar computes with *no* noise
    and *no* ADC saturation — symmetric per-tensor quantization of both
    operands, integer matmul, rescale.

    The Pallas kernel must match this exactly when the ADC never clips
    (small k) and noise_key is None; tests also check the clipping path
    against ``crossbar_clipped_ref``.
    """
    def q(t, bits):
        qmax = float(2 ** (bits - 1) - 1)
        scale = jnp.maximum(jnp.max(jnp.abs(t)), 1e-12) / qmax
        return jnp.clip(jnp.round(t / scale), -qmax, qmax), scale

    x_q, sx = q(x, act_bits)
    w_q, sw = q(w, weight_bits)
    return (x_q @ w_q) * (sx * sw)


def crossbar_clipped_ref(x: jax.Array, w: jax.Array, *, act_bits: int = 8,
                         weight_bits: int = 8, cell_bits: int = 2,
                         rows_per_xbar: int = 128, adc_bits: int = 8) -> jax.Array:
    """Bit-sliced oracle *with* per-crossbar ADC saturation, mirroring the
    kernel's offset-binary digit decomposition step by step (but with
    plain jnp loops over slices and crossbar segments)."""
    def q(t, bits):
        qmax = float(2 ** (bits - 1) - 1)
        scale = jnp.maximum(jnp.max(jnp.abs(t)), 1e-12) / qmax
        return jnp.clip(jnp.round(t / scale), -qmax, qmax).astype(jnp.int32), scale

    x_q, sx = q(x, act_bits)
    w_q, sw = q(w, weight_bits)
    n_slices = weight_bits // cell_bits
    offset = 2 ** (weight_bits - 1)
    w_off = w_q + offset

    k = x.shape[1]
    pad_k = (-k) % rows_per_xbar
    x_p = jnp.pad(x_q, ((0, 0), (0, pad_k)))
    w_p = jnp.pad(w_off, ((0, pad_k), (0, 0)))
    kp = k + pad_k
    n_xbars = kp // rows_per_xbar

    act_max = float(2 ** (act_bits - 1) - 1)
    adc_max = (2 ** adc_bits - 1) * act_max

    total = jnp.zeros((x.shape[0], w.shape[1]), jnp.float32)
    for s in range(n_slices - 1, -1, -1):
        digit = ((w_p // (4 ** s)) % 4).astype(jnp.float32)
        acc = jnp.zeros_like(total)
        for b in range(n_xbars):
            rows = slice(b * rows_per_xbar, (b + 1) * rows_per_xbar)
            part = x_p[:, rows].astype(jnp.float32) @ digit[rows]
            acc = acc + jnp.clip(part, -adc_max, adc_max)
        total = total + acc * float(4 ** s)
    x_row_sum = jnp.sum(x_q.astype(jnp.float32), axis=1, keepdims=True)
    total = total - float(offset) * x_row_sum
    return total * (sx * sw)


def layernorm_ref(x: jax.Array, gamma: jax.Array, beta: jax.Array, *,
                  eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return (((x32 - mean) / jnp.sqrt(var + eps)) * gamma + beta).astype(x.dtype)


def gelu_ref(x: jax.Array) -> jax.Array:
    """tanh-approximate GELU (matches the deployed kernel; erf is not
    parseable by the Rust loader's XLA)."""
    return jax.nn.gelu(x.astype(jnp.float32), approximate=True).astype(x.dtype)


def softmax_ref(x: jax.Array) -> jax.Array:
    return jax.nn.softmax(x.astype(jnp.float32), axis=-1).astype(x.dtype)
