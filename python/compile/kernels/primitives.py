"""Auxiliary Pallas kernels: layer-norm and GELU (paper Table 1: L-1, FF-1/2).

These are the "additional computations" (§1) that force baseline PIM
accelerators to round-trip to a host — TransPIM/HAIMA offload softmax and
normalization to the host over the interposer (§5.3), while HeTraX executes
them on the SM tier. Here they are row-tiled Pallas kernels so the whole
encoder block lowers into one HLO module.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 128

# GELU uses the tanh approximation: the `erf` HLO opcode only exists in
# XLA > 0.5.1, and the Rust loader's HLO-text parser (xla_extension 0.5.1)
# rejects it. tanh lowers to a classic opcode everywhere.
SQRT_2_OVER_PI = math.sqrt(2.0 / math.pi)
GELU_C = 0.044715


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[...] + b_ref[...]).astype(o_ref.dtype)


def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array, *,
              eps: float = 1e-5, block_rows: int = DEFAULT_BLOCK_ROWS,
              interpret: bool = True) -> jax.Array:
    """Row-wise LayerNorm over the last axis of a (rows, d) array."""
    if x.ndim != 2:
        raise ValueError(f"expected (rows, d), got {x.shape}")
    rows, d = x.shape
    block_rows = min(block_rows, rows)
    if rows % block_rows != 0:
        pad = (-rows) % block_rows
        out = layernorm(jnp.pad(x, ((0, pad), (0, 0))), gamma, beta, eps=eps,
                        block_rows=block_rows, interpret=interpret)
        return out[:rows]
    kernel = functools.partial(_layernorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
            pl.BlockSpec((d,), lambda r: (0,)),
            pl.BlockSpec((d,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, gamma, beta)


def _gelu_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    inner = SQRT_2_OVER_PI * (x + GELU_C * x * x * x)
    o_ref[...] = (0.5 * x * (1.0 + jnp.tanh(inner))).astype(o_ref.dtype)


def gelu(x: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS,
         interpret: bool = True) -> jax.Array:
    """tanh-approximate GELU, row-tiled (see module note on erf)."""
    if x.ndim != 2:
        raise ValueError(f"expected (rows, d), got {x.shape}")
    rows, d = x.shape
    block_rows = min(block_rows, rows)
    if rows % block_rows != 0:
        pad = (-rows) % block_rows
        return gelu(jnp.pad(x, ((0, pad), (0, 0))), block_rows=block_rows,
                    interpret=interpret)[:rows]
    return pl.pallas_call(
        _gelu_kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda r: (r, 0))],
        out_specs=pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x)


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


def softmax(x: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS,
            interpret: bool = True) -> jax.Array:
    """Numerically-stable row softmax (used by the classifier head)."""
    if x.ndim != 2:
        raise ValueError(f"expected (rows, d), got {x.shape}")
    rows, d = x.shape
    block_rows = min(block_rows, rows)
    if rows % block_rows != 0:
        pad = (-rows) % block_rows
        return softmax(jnp.pad(x, ((0, pad), (0, 0))), block_rows=block_rows,
                       interpret=interpret)[:rows]
    return pl.pallas_call(
        _softmax_kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda r: (r, 0))],
        out_specs=pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x)
