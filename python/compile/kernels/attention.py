"""Fused score + online-softmax attention (paper §4.2, "MHA").

The paper's SM-tier trick: scores for sequence blocks are computed
row-block-wise, softmax is evaluated *online* (running max / running sum
carried across K blocks) and the weighted sum with V happens in the same
pass — "attention values are computed without the need to write
intermediate matrices back to DRAM".  On a TPU-class machine that is the
flash-attention schedule: Q blocks are grid-parallel and stay resident in
VMEM, K/V are streamed through VMEM block by block, and the (S×S) score
matrix never exists in HBM.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper tiles for an
SM's register file / L1; we tile with ``BlockSpec`` for VMEM and size the
blocks for the 128×128 MXU.  ``interpret=True`` everywhere — the CPU PJRT
plugin cannot run Mosaic custom-calls; real-TPU efficiency is estimated
statically in DESIGN.md.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned default tile sizes. Q tile rows × head_dim must fit VMEM
# together with one K/V tile; see vmem_footprint_bytes() below.
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128

# Large negative used to mask padding positions before softmax. Chosen to
# survive fp32 exp() without producing NaNs (exp(-1e30) == 0.0 exactly).
NEG_INF = -1e30


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, sm_scale: float,
                      seq_len: int, causal: bool, block_q: int):
    """One (head, q-block) program: online softmax over K blocks.

    q_ref: (block_q, d)   resident for the whole program
    k_ref: (seq_len, d)   streamed logically in block_k chunks
    v_ref: (seq_len, d)
    o_ref: (block_q, d)
    """
    q = q_ref[...].astype(jnp.float32)
    q_index = pl.program_id(1)  # which q block (axis 0 is the head)

    num_k_blocks = pl.cdiv(seq_len, block_k)

    def body(kb, carry):
        acc, m_prev, l_prev = carry
        k = pl.load(k_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        # Scores for this (q-block, k-block) tile: (block_q, block_k).
        s = jax.lax.dot_general(
            q, k.astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * sm_scale
        if causal:
            q_pos = q_index * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            k_pos = kb * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        # Online softmax update (running max m, running denominator l).
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)            # rescale old accumulator
        p = jnp.exp(s - m_new[:, None])            # (block_q, block_k)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jax.lax.dot_general(
            p, v.astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return acc, m_new, l_new

    d = q_ref.shape[-1]
    init = (
        jnp.zeros((block_q, d), jnp.float32),
        jnp.full((block_q,), NEG_INF, jnp.float32),
        jnp.zeros((block_q,), jnp.float32),
    )
    acc, _, l = jax.lax.fori_loop(0, num_k_blocks, body, init)
    # l is > 0 for every valid row (each row sees at least its own diagonal
    # position when causal, and all positions otherwise).
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def fused_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = False,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    sm_scale: float | None = None,
                    interpret: bool = True) -> jax.Array:
    """softmax(Q Kᵀ / √d) V per head, with the fused online-softmax schedule.

    Args:
      q, k, v: (heads, seq, head_dim). For MQA, k/v may have 1 head and are
        broadcast. seq must be positive; blocks are clamped to seq.
    Returns:
      (heads, seq, head_dim) with q.dtype.
    """
    if q.ndim != 3:
        raise ValueError(f"expected (heads, seq, head_dim), got {q.shape}")
    h, s, d = q.shape
    if k.shape[0] != h:
        if k.shape[0] != 1:
            raise ValueError(f"k heads {k.shape[0]} incompatible with q heads {h}")
        k = jnp.broadcast_to(k, (h,) + k.shape[1:])
        v = jnp.broadcast_to(v, (h,) + v.shape[1:])
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    if s % block_q != 0 or s % block_k != 0:
        # Pad to block multiples; padded K positions are masked by length.
        pad_q = (-s) % block_q
        pad_k = (-s) % block_k
        # Keep it simple: pad both to the same padded length.
        pad = max(pad_q, pad_k)
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0)), constant_values=0.0)
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        # Mask padded keys by pushing their scores to NEG_INF via a huge
        # negative bias hidden in the padded K rows: instead we run causal
        # logic-free and slice; padded K columns contribute exp(s) with the
        # *real* running max, so we mask by zeroing V and subtracting their
        # probability mass. Cleanest correct approach: recurse on the padded
        # array with an explicit causal=False mask via key padding. For the
        # shapes used in this project (powers of two), this path is only a
        # safety net; implement by slicing the exact computation.
        out = _fused_attention_padded(qp, kp, vp, s, causal=causal,
                                      block_q=block_q, block_k=block_k,
                                      sm_scale=sm_scale, interpret=interpret)
        return out[:, :s, :]
    kernel = functools.partial(
        _attention_kernel, block_k=block_k, sm_scale=sm_scale, seq_len=s,
        causal=causal, block_q=block_q)
    grid = (h, s // block_q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda hh, qq: (hh, qq, 0)),
            pl.BlockSpec((None, s, d), lambda hh, qq: (hh, 0, 0)),
            pl.BlockSpec((None, s, d), lambda hh, qq: (hh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda hh, qq: (hh, qq, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s, d), q.dtype),
        interpret=interpret,
    )(q, k, v)


def _fused_attention_padded(qp, kp, vp, true_len, *, causal, block_q, block_k,
                            sm_scale, interpret):
    """Padded fallback: mask key positions ≥ true_len inside the kernel."""
    h, sp, d = qp.shape

    def kernel(q_ref, k_ref, v_ref, o_ref):
        q = q_ref[...].astype(jnp.float32)
        q_index = pl.program_id(1)
        num_k_blocks = pl.cdiv(sp, block_k)

        def body(kb, carry):
            acc, m_prev, l_prev = carry
            k = pl.load(k_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
            v = pl.load(v_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
            s = jax.lax.dot_general(
                q, k.astype(jnp.float32), (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * sm_scale
            k_pos = kb * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            mask = k_pos < true_len
            if causal:
                q_pos = q_index * block_q + jax.lax.broadcasted_iota(
                    jnp.int32, (block_q, block_k), 0)
                mask = jnp.logical_and(mask, q_pos >= k_pos)
            s = jnp.where(mask, s, NEG_INF)
            m_cur = jnp.max(s, axis=-1)
            m_new = jnp.maximum(m_prev, m_cur)
            alpha = jnp.exp(m_prev - m_new)
            p = jnp.exp(s - m_new[:, None])
            l_new = l_prev * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[:, None] + jax.lax.dot_general(
                p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            return acc, m_new, l_new

        init = (jnp.zeros((block_q, d), jnp.float32),
                jnp.full((block_q,), NEG_INF, jnp.float32),
                jnp.zeros((block_q,), jnp.float32))
        acc, _, l = jax.lax.fori_loop(0, num_k_blocks, body, init)
        l = jnp.maximum(l, 1e-30)  # padded q rows have zero mass
        o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)

    grid = (h, sp // block_q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda hh, qq: (hh, qq, 0)),
            pl.BlockSpec((None, sp, d), lambda hh, qq: (hh, 0, 0)),
            pl.BlockSpec((None, sp, d), lambda hh, qq: (hh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda hh, qq: (hh, qq, 0)),
        out_shape=jax.ShapeDtypeStruct((h, sp, d), qp.dtype),
        interpret=interpret,
    )(qp, kp, vp)


def vmem_footprint_bytes(seq: int, head_dim: int, *, block_q: int = DEFAULT_BLOCK_Q,
                         block_k: int = DEFAULT_BLOCK_K,
                         dtype_bytes: int = 4) -> int:
    """Static VMEM estimate for one program instance (see DESIGN.md §Perf).

    Counts the Q tile, one K and one V tile (the streamed working set), the
    f32 accumulator, carries, and the output tile. This is the number used
    for the real-TPU feasibility estimate; interpret-mode wallclock is not
    a TPU proxy.
    """
    block_q = min(block_q, seq)
    block_k = min(block_k, seq)
    q_tile = block_q * head_dim * dtype_bytes
    kv_tiles = 2 * block_k * head_dim * dtype_bytes
    acc = block_q * head_dim * 4
    carries = 2 * block_q * 4
    out_tile = block_q * head_dim * dtype_bytes
    scores = block_q * block_k * 4
    return q_tile + kv_tiles + acc + carries + out_tile + scores


def mxu_utilization_estimate(seq: int, head_dim: int, *,
                             block_q: int = DEFAULT_BLOCK_Q,
                             block_k: int = DEFAULT_BLOCK_K) -> float:
    """Fraction of MXU lanes busy for the two dot_generals, by tile shape.

    The MXU is a 128×128 systolic array; a (m, k)·(k, n) matmul uses
    min(m,128)/128 × min(n,128)/128 of the array per pass (contraction dim
    is pipelined). Returns the FLOP-weighted average over the QKᵀ and PV
    products.
    """
    bq = min(block_q, seq)
    bk = min(block_k, seq)

    def tile_util(m, n):
        return (min(m, 128) / 128.0) * (min(n, 128) / 128.0)

    # QKᵀ: (bq × d)·(d × bk); PV: (bq × bk)·(bk × d). Equal FLOPs.
    return 0.5 * (tile_util(bq, bk) + tile_util(bq, head_dim))
