"""Layer-2: transformer forward pass (paper Table 1), composed from the
Pallas kernels in :mod:`compile.kernels`.

Implements every computational kernel row of Table 1:

  INPUT  X = I_emb + PositionalEncoding(I_emb)
  MHA-1  Q_i, K_i, V_i = X W_i^Q, X W_i^K, X W_i^V      (SM tier)
  MHA-2  S_i = softmax(Q Kᵀ / √d)                        (fused, SM tier)
  MHA-3  O_i = S_i V_i                                   (fused with MHA-2)
  MHA-4  H_m = concat(O_i) W^O                           (SM tier)
  L-1    M = LayerNorm(X + H_m)                          (SM tier)
  FF-1   X¹ = GeLU(M W^{F1})                             (ReRAM tier)
  FF-2   X² = GeLU(X¹ W^{F2})                            (ReRAM tier)
  L-2    LayerNorm(M + X²)

plus the architecture variants of §3: encoder-only, decoder-only (causal),
encoder-decoder (cross-attention), MQA (shared K/V across heads) and
parallel attention (MHA ∥ FF).

MHA-2/3 run through the fused online-softmax kernel; FF-1/2 run through the
simulated ReRAM crossbar kernel — mirroring where each kernel executes on
the HeTraX die. Everything is float32 here; the 16-bit deployment precision
is modeled on the Rust timing side.

This module is build-time only: it is lowered once by :mod:`compile.aot`
and never imported at runtime.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp

from .kernels import attention as attn_k
from .kernels import crossbar as xbar_k
from .kernels import primitives as prim_k


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Transformer dimensions (matches ``rust/src/model/zoo.rs``)."""
    name: str
    layers: int          # encoder layers (or decoder layers if decoder_only)
    d_model: int
    heads: int
    d_ff: int
    variant: str = "encoder_only"  # encoder_only|decoder_only|encoder_decoder|mqa|parallel

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.heads == 0
        return self.d_model // self.heads


# The model zoo of §5.1 (dims are the published checkpoints') plus the tiny
# config the AOT artifacts are built from.
MODEL_ZOO = {
    "bert-tiny": ModelConfig("bert-tiny", 2, 128, 2, 512),
    "bert-base": ModelConfig("bert-base", 12, 768, 12, 3072),
    "bert-large": ModelConfig("bert-large", 24, 1024, 16, 4096),
    "bart-base": ModelConfig("bart-base", 6, 768, 12, 3072, "encoder_decoder"),
    "bart-large": ModelConfig("bart-large", 12, 1024, 16, 4096, "encoder_decoder"),
}

# Flat parameter order for one encoder block — the AOT manifest and the
# Rust runtime both rely on this exact order.
BLOCK_PARAM_NAMES = (
    "wq", "wk", "wv", "wo", "ln1_g", "ln1_b", "wf1", "wf2", "ln2_g", "ln2_b",
)


def block_param_shapes(cfg: ModelConfig) -> dict[str, tuple[int, ...]]:
    d, f = cfg.d_model, cfg.d_ff
    kv_d = cfg.head_dim if cfg.variant == "mqa" else d
    return {
        "wq": (d, d), "wk": (d, kv_d), "wv": (d, kv_d), "wo": (d, d),
        "ln1_g": (d,), "ln1_b": (d,),
        "wf1": (d, f), "wf2": (f, d),
        "ln2_g": (d,), "ln2_b": (d,),
    }


def init_block_params(key: jax.Array, cfg: ModelConfig) -> list[jax.Array]:
    """Xavier-ish init, returned in BLOCK_PARAM_NAMES order."""
    shapes = block_param_shapes(cfg)
    params = []
    for name in BLOCK_PARAM_NAMES:
        shape = shapes[name]
        key, sub = jax.random.split(key)
        if name.endswith("_g"):
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith("_b"):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[0]
            params.append(jax.random.normal(sub, shape, jnp.float32)
                          / math.sqrt(fan_in))
    return params


def _split_heads(x: jax.Array, heads: int) -> jax.Array:
    """(s, d) → (heads, s, d/heads)."""
    s, d = x.shape
    return x.reshape(s, heads, d // heads).transpose(1, 0, 2)


def _merge_heads(x: jax.Array) -> jax.Array:
    """(heads, s, hd) → (s, heads·hd)."""
    h, s, hd = x.shape
    return x.transpose(1, 0, 2).reshape(s, h * hd)


def multi_head_attention(x: jax.Array, wq, wk, wv, wo, *, heads: int,
                         causal: bool = False, mqa: bool = False,
                         kv_source: jax.Array | None = None,
                         interpret: bool = True) -> jax.Array:
    """MHA-1..4 of Table 1. ``kv_source`` enables cross-attention (BART).

    With ``mqa`` the K/V projections produce a single shared head
    (wk/wv: (d, head_dim)).
    """
    kv_in = x if kv_source is None else kv_source
    q = _split_heads(x @ wq, heads)                      # MHA-1
    if mqa:
        k = (kv_in @ wk)[None, :, :]                     # one shared head
        v = (kv_in @ wv)[None, :, :]
    else:
        k = _split_heads(kv_in @ wk, heads)
        v = _split_heads(kv_in @ wv, heads)
    o = attn_k.fused_attention(q, k, v, causal=causal,
                               interpret=interpret)      # MHA-2 + MHA-3
    return _merge_heads(o) @ wo                          # MHA-4


def feed_forward(m: jax.Array, wf1, wf2, *, on_reram: bool = True,
                 interpret: bool = True) -> jax.Array:
    """FF-1/FF-2 of Table 1. On the ReRAM tier both GEMMs run through the
    crossbar kernel (weights stationary); ``on_reram=False`` gives the
    ideal digital path used for ablation."""
    if on_reram:
        x1 = prim_k.gelu(
            xbar_k.crossbar_matmul(m, wf1, interpret=interpret),
            interpret=interpret)
        x2 = prim_k.gelu(
            xbar_k.crossbar_matmul(x1, wf2, interpret=interpret),
            interpret=interpret)
    else:
        x1 = prim_k.gelu(m @ wf1, interpret=interpret)
        x2 = prim_k.gelu(x1 @ wf2, interpret=interpret)
    return x2


def encoder_block(x: jax.Array, params: Sequence[jax.Array], cfg: ModelConfig,
                  *, causal: bool = False, on_reram: bool = True,
                  interpret: bool = True) -> jax.Array:
    """One full Table-1 block. ``params`` in BLOCK_PARAM_NAMES order."""
    wq, wk, wv, wo, ln1_g, ln1_b, wf1, wf2, ln2_g, ln2_b = params
    mqa = cfg.variant == "mqa"
    if cfg.variant == "parallel":
        # Parallel attention (§3): MHA and FF both read the *same*
        # (pre-normalized) input and their outputs are summed — the PaLM
        # formulation; on HeTraX the two tiers compute concurrently
        # (§5.3 "fused MHA-FF").
        x_norm = prim_k.layernorm(x, ln1_g, ln1_b, interpret=interpret)
        h = multi_head_attention(x_norm, wq, wk, wv, wo, heads=cfg.heads,
                                 causal=causal, mqa=mqa, interpret=interpret)
        f = feed_forward(x_norm, wf1, wf2, on_reram=on_reram,
                         interpret=interpret)
        y = x + h + f
        return prim_k.layernorm(y, ln2_g, ln2_b, interpret=interpret)
    h = multi_head_attention(x, wq, wk, wv, wo, heads=cfg.heads,
                             causal=causal, mqa=mqa, interpret=interpret)
    m = prim_k.layernorm(x + h, ln1_g, ln1_b, interpret=interpret)   # L-1
    x2 = feed_forward(m, wf1, wf2, on_reram=on_reram, interpret=interpret)
    return prim_k.layernorm(m + x2, ln2_g, ln2_b, interpret=interpret)


def positional_encoding(seq: int, d_model: int) -> jax.Array:
    """Sinusoidal positional encoding (Table 1 INPUT row)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    i = jnp.arange(d_model // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, 2.0 * i / d_model)
    pe = jnp.zeros((seq, d_model), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe


def encoder(x_emb: jax.Array, layer_params: Sequence[Sequence[jax.Array]],
            cfg: ModelConfig, *, interpret: bool = True,
            on_reram: bool = True) -> jax.Array:
    """Stack of encoder blocks over positionally-encoded embeddings."""
    x = x_emb + positional_encoding(x_emb.shape[0], cfg.d_model)
    causal = cfg.variant == "decoder_only"
    for params in layer_params:
        x = encoder_block(x, params, cfg, causal=causal,
                          on_reram=on_reram, interpret=interpret)
    return x
