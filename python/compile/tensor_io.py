"""HTX tensor archive — the weight/dataset interchange format.

A deliberately simple binary container read by ``rust/src/util/tensor_io.rs``
(no numpy/npz dependency on the Rust side). Layout, all little-endian:

    magic   b"HTX1"
    count   u32
    count × records:
        name_len u32, name utf-8 bytes
        dtype    u8   (0 = f32, 1 = i32, 2 = u8)
        ndim     u32, dims u32 × ndim
        data     raw bytes, C order

The Python writer and Rust reader are cross-checked by
``python/tests/test_tensor_io.py`` and ``rust/tests/integration.rs`` via a
golden file in ``artifacts/``.
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"HTX1"
_DTYPES = {0: np.float32, 1: np.int32, 2: np.uint8}
_DTYPE_CODES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1,
                np.dtype(np.uint8): 2}


def write_archive(path: str, tensors: dict[str, np.ndarray]) -> None:
    """Write named tensors. Order is preserved (dict order)."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.asarray(arr)
            if arr.ndim:  # ascontiguousarray would promote 0-d to 1-d
                arr = np.ascontiguousarray(arr)
            code = _DTYPE_CODES.get(arr.dtype)
            if code is None:
                raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", code))
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def read_archive(path: str) -> dict[str, np.ndarray]:
    """Read an HTX1 archive back into an ordered dict of arrays."""
    out: dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode("utf-8")
            (code,) = struct.unpack("<B", f.read(1))
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            dtype = np.dtype(_DTYPES[code])
            n = int(np.prod(dims, dtype=np.int64)) if ndim else 1
            data = f.read(n * dtype.itemsize)
            out[name] = np.frombuffer(data, dtype=dtype).reshape(tuple(dims)).copy()
    return out
