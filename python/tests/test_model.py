"""L2 model: shapes, variants, Table-1 structure, and AOT manifest order."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import classifier as clf
from compile import model as model_lib


CFG = model_lib.ModelConfig("t", 1, 32, 2, 64)


def params_for(cfg, seed=0):
    return model_lib.init_block_params(jax.random.PRNGKey(seed), cfg)


def x_for(cfg, s=16, seed=9):
    return jax.random.normal(jax.random.PRNGKey(seed), (s, cfg.d_model))


@pytest.mark.parametrize("variant", ["encoder_only", "decoder_only", "mqa",
                                     "parallel"])
def test_block_shapes(variant):
    cfg = model_lib.ModelConfig("t", 1, 32, 2, 64, variant)
    x = x_for(cfg)
    out = model_lib.encoder_block(x, params_for(cfg), cfg,
                                  causal=variant == "decoder_only")
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()


def test_zoo_dims_match_published():
    z = model_lib.MODEL_ZOO
    assert (z["bert-base"].layers, z["bert-base"].d_model,
            z["bert-base"].heads, z["bert-base"].d_ff) == (12, 768, 12, 3072)
    assert (z["bert-large"].layers, z["bert-large"].d_model,
            z["bert-large"].heads, z["bert-large"].d_ff) == (24, 1024, 16, 4096)
    assert z["bert-tiny"].d_model == 128 and z["bert-tiny"].layers == 2
    for m in z.values():
        assert m.d_ff == 4 * m.d_model  # §4.2: hidden 4× model dim
        assert m.d_model % m.heads == 0


def test_mqa_param_shapes_shrink():
    cfg = model_lib.ModelConfig("t", 1, 32, 4, 64, "mqa")
    shapes = model_lib.block_param_shapes(cfg)
    assert shapes["wk"] == (32, 8) and shapes["wv"] == (32, 8)
    assert shapes["wq"] == (32, 32)


def test_causal_block_is_causal():
    """Changing a late token must not affect early outputs in the decoder.

    Uses the digital FF path: the crossbar kernel's *per-tensor* activation
    quantization scale couples all rows by design (the DAC range is shared
    across the tile), so exact causality only holds pre-quantization.
    """
    cfg = model_lib.ModelConfig("t", 1, 32, 2, 64, "decoder_only")
    p = params_for(cfg)
    x = x_for(cfg, s=16)
    out1 = model_lib.encoder_block(x, p, cfg, causal=True, on_reram=False)
    x2 = x.at[12].add(5.0)
    out2 = model_lib.encoder_block(x2, p, cfg, causal=True, on_reram=False)
    np.testing.assert_allclose(np.asarray(out1[:12]), np.asarray(out2[:12]),
                               atol=1e-5)
    assert not np.allclose(np.asarray(out1[12:]), np.asarray(out2[12:]))


def test_causal_block_quantized_rows_near_causal():
    """On the ReRAM path the quantization-scale coupling must stay tiny."""
    cfg = model_lib.ModelConfig("t", 1, 32, 2, 64, "decoder_only")
    p = params_for(cfg)
    x = x_for(cfg, s=16)
    out1 = model_lib.encoder_block(x, p, cfg, causal=True)
    out2 = model_lib.encoder_block(x.at[12].add(5.0), p, cfg, causal=True)
    assert np.abs(np.asarray(out1[:12]) - np.asarray(out2[:12])).max() < 0.05


def test_non_causal_block_is_not_causal():
    p = params_for(CFG)
    x = x_for(CFG, s=16)
    out1 = model_lib.encoder_block(x, p, CFG)
    out2 = model_lib.encoder_block(x.at[12].add(5.0), p, CFG)
    assert not np.allclose(np.asarray(out1[:12]), np.asarray(out2[:12]))


def test_on_reram_close_to_digital():
    """The crossbar FF path quantizes: outputs differ slightly but stay
    within the 8-bit error budget after layernorm."""
    p = params_for(CFG)
    x = x_for(CFG)
    reram = np.asarray(model_lib.encoder_block(x, p, CFG, on_reram=True))
    digital = np.asarray(model_lib.encoder_block(x, p, CFG, on_reram=False))
    assert np.abs(reram - digital).max() < 0.1
    assert not np.array_equal(reram, digital)


def test_positional_encoding_properties():
    pe = np.asarray(model_lib.positional_encoding(64, 32))
    assert pe.shape == (64, 32)
    np.testing.assert_allclose(pe[0, 0::2], 0.0, atol=1e-7)   # sin(0)
    np.testing.assert_allclose(pe[0, 1::2], 1.0, atol=1e-7)   # cos(0)
    assert np.abs(pe).max() <= 1.0 + 1e-6
    # Distinct positions get distinct encodings.
    assert not np.allclose(pe[1], pe[2])


def test_encoder_stacks_layers():
    cfg = model_lib.ModelConfig("t", 2, 32, 2, 64)
    layer_params = [params_for(cfg, s) for s in (0, 1)]
    x = x_for(cfg)
    out = model_lib.encoder(x, layer_params, cfg)
    assert out.shape == x.shape
    # Two different layers must not act like one layer applied twice.
    out_same = model_lib.encoder(x, [layer_params[0]] * 2, cfg)
    assert not np.allclose(np.asarray(out), np.asarray(out_same))


def test_classifier_param_names_cover_shapes():
    shapes = clf.param_shapes()
    assert set(clf.PARAM_NAMES) == set(shapes)
    assert clf.PARAM_NAMES[-2:] == ("head_w", "head_b")
    assert shapes["l0_wf1"] == (clf.D_MODEL, clf.D_FF)


def test_classifier_forward_batch_matches_single():
    params = clf.init_params(jax.random.PRNGKey(0))
    x, _ = clf.make_dataset(clf.TASKS["sst2-syn"], jax.random.PRNGKey(1), 3)
    batch = clf.forward_batch(x, params)
    singles = jnp.stack([clf.forward_single(xx, params) for xx in x])
    np.testing.assert_allclose(np.asarray(batch), np.asarray(singles),
                               atol=1e-5)


def test_datasets_are_balanced_and_deterministic():
    for name, task in clf.TASKS.items():
        x, y = clf.make_dataset(task, jax.random.PRNGKey(5), 512)
        assert x.shape == (512, clf.SEQ_LEN, clf.D_MODEL)
        frac = float(jnp.mean(y.astype(jnp.float32)))
        assert 0.4 < frac < 0.6, (name, frac)
        x2, y2 = clf.make_dataset(task, jax.random.PRNGKey(5), 512)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(y2))
        np.testing.assert_array_equal(np.asarray(x), np.asarray(x2))
