"""ReRAM crossbar kernel vs the quantized-matmul oracles."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import crossbar, ref

hypothesis.settings.register_profile(
    "kernels", max_examples=20, deadline=None,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("kernels")


def rand(seed, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


@hypothesis.given(
    m=st.integers(1, 16),
    k=st.sampled_from([8, 32, 100, 128, 200, 384]),
    n=st.sampled_from([8, 64, 128, 130, 256]),
    seed=st.integers(0, 2**16),
)
def test_matches_clipped_oracle(m, k, n, seed):
    x = rand(seed, (m, k))
    w = rand(seed + 1, (k, n))
    out = crossbar.crossbar_matmul(x, w)
    exp = ref.crossbar_clipped_ref(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=1e-4, rtol=1e-4)


@hypothesis.given(seed=st.integers(0, 2**16))
def test_no_clip_matches_plain_quantized(seed):
    """With small k (column sums below ADC range) the crossbar equals the
    plain quantized matmul oracle."""
    x = rand(seed, (8, 32), scale=0.5)
    w = rand(seed + 1, (32, 64), scale=0.5)
    out = crossbar.crossbar_matmul(x, w)
    exp = ref.crossbar_ref(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=1e-4, rtol=1e-4)


def test_quantized_matmul_close_to_fp():
    """Quantization error of the full pipeline stays within the 8-bit
    budget: relative Frobenius error below ~2% (symmetric per-tensor
    8-bit on both operands over k=256)."""
    x = rand(0, (32, 256))
    w = rand(1, (256, 128))
    out = np.asarray(crossbar.crossbar_matmul(x, w))
    exact = np.asarray(x @ w)
    rel = np.linalg.norm(out - exact) / np.linalg.norm(exact)
    assert rel < 0.02, rel


def test_noise_increases_with_temperature():
    x = rand(0, (8, 128))
    w = rand(1, (128, 128))
    clean = np.asarray(crossbar.crossbar_matmul(x, w))
    errs = []
    for t in (300.0, 350.0, 400.0):
        noisy = np.asarray(crossbar.crossbar_matmul(
            x, w, temp_kelvin=t, noise_key=jax.random.PRNGKey(7)))
        errs.append(np.abs(noisy - clean).mean())
    assert errs[0] < errs[1] < errs[2]


def test_noise_zero_without_key():
    x = rand(0, (4, 64))
    w = rand(1, (64, 32))
    a = np.asarray(crossbar.crossbar_matmul(x, w, temp_kelvin=400.0))
    b = np.asarray(crossbar.crossbar_matmul(x, w, temp_kelvin=300.0))
    np.testing.assert_array_equal(a, b)


def test_eq5_sigma_formula():
    """σ = sqrt(4 G k_B T F) / V — checked against hand-computed value and
    the √T scaling law."""
    s300 = crossbar.conductance_noise_sigma(300.0)
    expected = np.sqrt(4 * crossbar.RERAM_G_ON * crossbar.BOLTZMANN * 300.0
                       * crossbar.RERAM_FREQ) / crossbar.RERAM_READ_V
    assert s300 == pytest.approx(expected)
    assert crossbar.conductance_noise_sigma(1200.0) == pytest.approx(2 * s300)


@hypothesis.given(seed=st.integers(0, 1000))
def test_weight_quantization_roundtrip(seed):
    w = rand(seed, (16, 16), scale=3.0)
    w_q, scale = crossbar.quantize_weights(w)
    assert int(jnp.max(jnp.abs(w_q))) <= 127
    np.testing.assert_allclose(np.asarray(w_q * scale), np.asarray(w),
                               atol=float(scale) / 2 + 1e-7)


def test_slice_weights_reassembles():
    w = rand(3, (32, 8), scale=2.0)
    w_q, _ = crossbar.quantize_weights(w)
    slices, offset = crossbar.slice_weights(w_q)
    assert slices.shape == (crossbar.NUM_SLICES, 32, 8)
    assert int(jnp.min(slices)) >= 0 and int(jnp.max(slices)) <= 3
    weights = jnp.array([4 ** i for i in range(crossbar.NUM_SLICES - 1, -1, -1)],
                        jnp.int32)
    rebuilt = jnp.tensordot(weights, slices, axes=1) - offset
    np.testing.assert_array_equal(np.asarray(rebuilt), np.asarray(w_q))


def test_crossbars_required():
    # BERT-Large FF1: (1024, 4096) → 8 × 32 tiles × 4 slices = 1024 crossbars
    assert crossbar.crossbars_required(1024, 4096) == 8 * 32 * 4
    assert crossbar.crossbars_required(1, 1) == 4
    assert crossbar.crossbars_required(128, 128) == 4


def test_shape_mismatch_raises():
    with pytest.raises(ValueError):
        crossbar.crossbar_matmul(jnp.zeros((2, 3)), jnp.zeros((4, 5)))
