"""AOT pipeline: HLO text validity, manifest consistency, executability.

The lowered HLO must (a) parse as HLO text, (b) contain no custom-calls
(the CPU PJRT client cannot execute Mosaic/ShapeAssertion custom-calls),
and (c) produce the same numbers as the jitted python function when run
through the XLA client — the same check the Rust runtime_e2e test performs.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, classifier as clf, model as model_lib
from compile.kernels import attention as attn_k

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_attention_hlo_text_parses_and_is_custom_call_free():
    text, inputs, outputs = aot.lower_attention()
    assert text.startswith("HloModule")
    assert "custom-call" not in text, "CPU PJRT cannot run custom-calls"
    assert len(inputs) == 3 and len(outputs) == 1


def test_encoder_block_hlo_inputs_match_param_names():
    text, inputs, _ = aot.lower_encoder_block()
    assert [n for n, _ in inputs] == ["x"] + list(model_lib.BLOCK_PARAM_NAMES)
    assert "custom-call" not in text


@pytest.mark.parametrize("variant", ["mqa", "parallel", "decoder_only"])
def test_variant_blocks_lower(variant):
    text, inputs, _ = aot.lower_encoder_block(variant)
    assert text.startswith("HloModule")
    assert "custom-call" not in text


def test_attention_hlo_structure_and_jit_numerics():
    """Structural validity of the HLO text + numerics of the function it
    was lowered from. (Cross-language execution of the *text* itself is
    validated by rust/tests/runtime_e2e.rs, which loads this exact
    artifact through the xla crate and checks against an independent
    Rust reference — jaxlib's private compile API is too unstable to
    re-execute the text from Python.)"""
    text, inputs, _ = aot.lower_attention()
    # Entry computation with 3 parameters of the declared shapes.
    assert "ENTRY" in text
    for name, shape in inputs:
        dims = ",".join(str(d) for d in shape)
        assert f"f32[{dims}]" in text, f"{name} {shape} missing from HLO"
    # The fused kernel lowers to an online-softmax loop: a `while` op and
    # exponentials must be present, and no full (seq × seq) f32 score
    # tensor may appear as an intermediate shape.
    assert "while" in text
    assert "exponential" in text
    s = aot.ATTN_SEQ
    assert f"f32[{aot.ATTN_HEADS},{s},{s}]" not in text, "S materialized!"

    q = jax.random.normal(jax.random.PRNGKey(0),
                          (aot.ATTN_HEADS, aot.ATTN_SEQ, aot.ATTN_HEAD_DIM))
    k = jax.random.normal(jax.random.PRNGKey(1), q.shape)
    v = jax.random.normal(jax.random.PRNGKey(2), q.shape)
    got = jax.jit(lambda a, b, c: attn_k.fused_attention(a, b, c))(q, k, v)
    from compile.kernels import ref
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.attention_ref(q, k, v)),
                               atol=1e-5, rtol=1e-5)


def test_manifest_written(tmp_path):
    """--skip-train writes all HLO artifacts + a consistent manifest."""
    import sys
    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path), "--skip-train"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    manifest = json.load(open(tmp_path / "manifest.json"))
    assert manifest["format"] == "hlo-text"
    for name, meta in manifest["artifacts"].items():
        path = tmp_path / meta["file"]
        assert path.exists(), name
        head = path.read_text()[:200]
        assert head.startswith("HloModule")
    assert manifest["classifier"]["param_names"] == list(clf.PARAM_NAMES)
    assert (tmp_path / "bert_tiny_weights.htx").exists()
    assert (tmp_path / "golden.htx").exists()


def test_built_artifacts_exist():
    """After `make artifacts` the canonical artifact set is present."""
    if not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")):
        pytest.skip("artifacts not built yet")
    manifest = json.load(open(os.path.join(ARTIFACTS, "manifest.json")))
    for meta in manifest["artifacts"].values():
        assert os.path.exists(os.path.join(ARTIFACTS, meta["file"]))
