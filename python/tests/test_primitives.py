"""LayerNorm / GELU / softmax Pallas kernels vs jnp oracles."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import primitives, ref

hypothesis.settings.register_profile(
    "kernels", max_examples=20, deadline=None,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("kernels")


def rand(seed, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


@hypothesis.given(rows=st.integers(1, 300), d=st.sampled_from([8, 32, 64, 128]),
                  seed=st.integers(0, 1000))
def test_layernorm(rows, d, seed):
    x = rand(seed, (rows, d), scale=3.0)
    g = rand(seed + 1, (d,)) + 1.0
    b = rand(seed + 2, (d,))
    out = primitives.layernorm(x, g, b)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.layernorm_ref(x, g, b)),
                               atol=1e-5, rtol=1e-5)


def test_layernorm_output_statistics():
    x = rand(0, (64, 128), scale=5.0)
    out = np.asarray(primitives.layernorm(x, jnp.ones(128), jnp.zeros(128)))
    np.testing.assert_allclose(out.mean(axis=-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(out.std(axis=-1), 1.0, atol=1e-2)


@hypothesis.given(rows=st.integers(1, 300), seed=st.integers(0, 1000))
def test_gelu(rows, seed):
    x = rand(seed, (rows, 32), scale=4.0)
    np.testing.assert_allclose(np.asarray(primitives.gelu(x)),
                               np.asarray(ref.gelu_ref(x)),
                               atol=1e-5, rtol=1e-5)


def test_gelu_known_values():
    x = jnp.array([[0.0, 1.0, -1.0, 10.0, -10.0]], jnp.float32)
    out = np.asarray(primitives.gelu(x))[0]
    np.testing.assert_allclose(out[0], 0.0, atol=1e-7)
    # tanh approximation: Φ(1)·1 ≈ 0.8412 (vs exact 0.8413).
    np.testing.assert_allclose(out[1], 0.841192, atol=1e-4)
    np.testing.assert_allclose(out[2], -0.158808, atol=1e-4)
    np.testing.assert_allclose(out[3], 10.0, atol=1e-5)
    np.testing.assert_allclose(out[4], 0.0, atol=1e-5)


@hypothesis.given(rows=st.integers(1, 200), d=st.sampled_from([2, 10, 64]),
                  seed=st.integers(0, 1000))
def test_softmax(rows, d, seed):
    x = rand(seed, (rows, d), scale=10.0)
    out = np.asarray(primitives.softmax(x))
    np.testing.assert_allclose(out, np.asarray(ref.softmax_ref(x)),
                               atol=1e-6, rtol=1e-5)
    np.testing.assert_allclose(out.sum(axis=-1), 1.0, atol=1e-6)


def test_softmax_extreme_values_stable():
    x = jnp.array([[1000.0, 0.0, -1000.0]], jnp.float32)
    out = np.asarray(primitives.softmax(x))
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out[0, 0], 1.0, atol=1e-6)
