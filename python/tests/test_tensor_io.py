"""HTX archive round-trip (the Rust side re-checks the golden file)."""

import os
import tempfile

import hypothesis
import hypothesis.strategies as st
import numpy as np
import pytest

from compile import tensor_io

hypothesis.settings.register_profile("io", max_examples=25, deadline=None)
hypothesis.settings.load_profile("io")


def roundtrip(tensors):
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "t.htx")
        tensor_io.write_archive(p, tensors)
        return tensor_io.read_archive(p)


@hypothesis.given(
    shape=st.lists(st.integers(0, 7), min_size=0, max_size=4),
    dtype=st.sampled_from([np.float32, np.int32, np.uint8]),
    seed=st.integers(0, 2**16),
)
def test_roundtrip_any_shape(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    arr = (rng.standard_normal(shape) * 100).astype(dtype)
    out = roundtrip({"t": arr})["t"]
    assert out.dtype == arr.dtype and out.shape == arr.shape
    np.testing.assert_array_equal(out, arr)


def test_order_preserved():
    tensors = {f"t{i}": np.full((2,), i, np.float32) for i in range(20)}
    out = roundtrip(tensors)
    assert list(out) == list(tensors)


def test_unicode_names():
    arr = np.ones((3,), np.float32)
    out = roundtrip({"wéight/λ_0": arr})
    np.testing.assert_array_equal(out["wéight/λ_0"], arr)


def test_bad_magic_rejected(tmp_path):
    p = tmp_path / "bad.htx"
    p.write_bytes(b"NOPE" + b"\x00" * 16)
    with pytest.raises(ValueError):
        tensor_io.read_archive(str(p))


def test_unsupported_dtype_rejected(tmp_path):
    with pytest.raises(TypeError):
        tensor_io.write_archive(str(tmp_path / "x.htx"),
                                {"t": np.zeros(3, np.float64)})


def test_golden_file_contents():
    """The golden archive written by aot.py must decode to known values
    (Rust integration tests read the same file)."""
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts",
                        "golden.htx")
    if not os.path.exists(path):
        pytest.skip("artifacts not built yet")
    t = tensor_io.read_archive(path)
    np.testing.assert_allclose(t["f32_2x3"],
                               np.arange(6, dtype=np.float32).reshape(2, 3) / 4.0)
    np.testing.assert_array_equal(t["i32_4"],
                                  np.array([-2, -1, 0, 2_000_000_000]))
    assert t["u8_scalar"] == 255
    assert t["f32_empty"].shape == (0, 5)
