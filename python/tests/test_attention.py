"""Fused-attention kernel vs the naive oracle.

Hypothesis sweeps shapes; fixed cases cover causal masking, MQA broadcast,
padding fallback, dtype handling, and numerical edge cases.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import attention, ref

hypothesis.settings.register_profile(
    "kernels", max_examples=25, deadline=None,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("kernels")


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def assert_close(a, b, atol=2e-5, rtol=2e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol, rtol=rtol)


@hypothesis.given(
    h=st.integers(1, 4),
    s=st.sampled_from([16, 64, 128, 192, 256]),
    d=st.sampled_from([16, 32, 64]),
    causal=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_matches_oracle(h, s, d, causal, seed):
    q = rand(seed, (h, s, d))
    k = rand(seed + 1, (h, s, d))
    v = rand(seed + 2, (h, s, d))
    out = attention.fused_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    exp = ref.attention_ref(q, k, v, causal=causal)
    assert_close(out, exp)


@hypothesis.given(s=st.integers(3, 97), seed=st.integers(0, 100))
def test_non_multiple_seq_padding_path(s, seed):
    """Sequence lengths that do not divide the block size hit the padded
    fallback; results must still match the oracle exactly."""
    q, k, v = (rand(seed + i, (2, s, 16)) for i in range(3))
    out = attention.fused_attention(q, k, v, block_q=32, block_k=32)
    assert_close(out, ref.attention_ref(q, k, v))


def test_causal_first_row_attends_self_only():
    q, k, v = (rand(i, (1, 64, 32)) for i in range(3))
    out = attention.fused_attention(q, k, v, causal=True)
    # Row 0 may only see position 0 → output row 0 == v[0].
    assert_close(out[0, 0], v[0, 0], atol=1e-5)


def test_mqa_broadcast_matches_explicit():
    h, s, d = 4, 128, 32
    q = rand(0, (h, s, d))
    k1 = rand(1, (1, s, d))
    v1 = rand(2, (1, s, d))
    out = attention.fused_attention(q, k1, v1)
    k4 = jnp.broadcast_to(k1, (h, s, d))
    v4 = jnp.broadcast_to(v1, (h, s, d))
    exp = attention.fused_attention(q, k4, v4)
    assert_close(out, exp, atol=0, rtol=0)


def test_scale_override():
    q, k, v = (rand(i, (2, 64, 32)) for i in range(3))
    out = attention.fused_attention(q, k, v, sm_scale=0.25)
    exp = ref.attention_ref(q, k, v, sm_scale=0.25)
    assert_close(out, exp)


def test_large_magnitude_inputs_stable():
    """Online softmax must not overflow for large score magnitudes."""
    q, k, v = (rand(i, (1, 128, 32), scale=30.0) for i in range(3))
    out = attention.fused_attention(q, k, v)
    assert np.isfinite(np.asarray(out)).all()
    assert_close(out, ref.attention_ref(q, k, v), atol=1e-4, rtol=1e-4)


def test_identical_keys_uniform_attention():
    """All-equal keys → softmax uniform → output = mean of V."""
    s, d = 64, 16
    q = rand(0, (1, s, d))
    k = jnp.ones((1, s, d), jnp.float32)
    v = rand(1, (1, s, d))
    out = attention.fused_attention(q, k, v)
    exp = jnp.broadcast_to(jnp.mean(v, axis=1, keepdims=True), (1, s, d))
    assert_close(out, exp, atol=1e-5)


def test_rejects_bad_rank():
    with pytest.raises(ValueError):
        attention.fused_attention(jnp.zeros((2, 2)), jnp.zeros((2, 2)),
                                  jnp.zeros((2, 2)))


def test_rejects_incompatible_heads():
    with pytest.raises(ValueError):
        attention.fused_attention(jnp.zeros((4, 8, 4)), jnp.zeros((2, 8, 4)),
                                  jnp.zeros((2, 8, 4)))


def test_vmem_footprint_monotone_in_blocks():
    small = attention.vmem_footprint_bytes(1024, 64, block_q=64, block_k=64)
    big = attention.vmem_footprint_bytes(1024, 64, block_q=256, block_k=256)
    assert small < big
    # Default config must fit a TPU core's ~16 MB VMEM with huge margin.
    assert attention.vmem_footprint_bytes(4096, 128) < 4 * 1024 * 1024


def test_mxu_utilization_range():
    u = attention.mxu_utilization_estimate(1024, 64)
    assert 0.0 < u <= 1.0
    # 128-wide tiles with 128 head dim → fully utilized.
    assert attention.mxu_utilization_estimate(1024, 128) == pytest.approx(1.0)
